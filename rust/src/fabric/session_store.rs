//! Parked-session store: checkpointed sessions between partitions.
//!
//! A parked session is everything needed to continue a streaming session
//! on *any* compatible partition, bit-identically: the RM snapshot bytes
//! ([`super::snapshot::snapshot_rm`]), the stream cursor (flits/samples
//! already processed), the **origin partition's RM seed** (a session
//! resumed on a different partition must keep the parameters it started
//! with), and — while the parking is transparent to the client — the live
//! inbox and score channel, so eviction and re-attach never disturb the
//! producer's `push`/`poll_scores` view.
//!
//! Three things park a session (see [`ParkReason`]): the idle-eviction
//! policy (`[fabric.server] idle_evict_flits`), an explicit
//! [`super::server::Session::suspend`], and a quarantined partition
//! evicting its tenant for resume elsewhere. Suspended sessions leave the
//! store as a serializable [`SessionTicket`] ("FSTK" magic, versioned,
//! CRC-framed) that survives a process boundary: `[fabric.server]
//! spill_dir` names a directory tickets can be spilled to and re-loaded
//! from by a fresh server.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::mpsc::Sender;
use std::sync::{Arc, Mutex};

use super::message::Flit;
use super::score_sink::crc32;
use super::server::SessionInbox;
use super::snapshot::{Reader, Writer};
use crate::config::RmKind;
use crate::detectors::DetectorKind;

/// Ticket header magic ("fSEAD Session TicKet"). Public because tickets
/// now travel over the wire — the network plane's `Suspended` frame
/// carries these bytes verbatim, and clients can sanity-check them.
pub const TICKET_MAGIC: [u8; 4] = *b"FSTK";
/// Ticket layout version; bump on any wire-format change. Public for the
/// same reason: a `Resume` frame's ticket must match the version of the
/// server it lands on, which need not be the process that minted it.
pub const TICKET_VERSION: u8 = 1;

/// Typed ticket-parse failures that callers need to tell apart — the
/// network plane maps [`TicketError::Version`] onto its own wire status
/// (`ticket_version`, distinct from plain `bad_ticket`) so a router
/// resuming onto a worker from a different build fails loud instead of
/// looking like wire garbage. Recover the variant from an
/// `anyhow::Error` with `err.downcast_ref::<TicketError>()`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TicketError {
    /// The bytes do not start with the `FSTK` magic.
    NotATicket,
    /// Well-formed header, but written by an incompatible layout version.
    Version { got: u8, want: u8 },
}

impl std::fmt::Display for TicketError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TicketError::NotATicket => write!(f, "not a session ticket (bad magic)"),
            TicketError::Version { got, want } => {
                write!(f, "unsupported ticket version {got} (this build writes {want})")
            }
        }
    }
}

impl std::error::Error for TicketError {}

/// Why a session was parked.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ParkReason {
    /// Idle-eviction: the partition reclaimed the slot; the parking is
    /// transparent and the session re-attaches when its inbox stirs.
    Idle,
    /// Explicit [`super::server::Session::suspend`] — the client is
    /// waiting to collect a [`SessionTicket`].
    Suspend,
    /// The partition was quarantined (fault supervisor rung 2); the
    /// session resumes on another partition from its last checkpoint.
    Quarantine,
}

/// A checkpointed session at rest: RM snapshot + stream cursor + the live
/// client channels (present while the parking is transparent; absent once
/// the state has crossed a process boundary as a ticket).
pub struct ParkedSession {
    pub id: u64,
    pub kind: RmKind,
    pub r: usize,
    pub lanes: usize,
    pub d: usize,
    /// RM seed of the partition the session *started* on — resuming with
    /// this seed is what makes continuation bit-identical anywhere.
    pub seed: u64,
    pub warmup: Arc<Vec<f32>>,
    /// Serialized window state; `None` for RMs with no host-visible state
    /// (a fresh resume builds and resets instead).
    pub snapshot: Option<Vec<u8>>,
    /// Input flits fully processed before parking.
    pub flits: u64,
    /// Valid samples scored before parking.
    pub samples: u64,
    /// Live inbox, still held by the client's `Session` — present for
    /// transparent parking, absent for ticket-resumed state.
    pub inbox: Option<SessionInbox>,
    /// Live score channel into the client's receiver.
    pub scores: Option<Sender<Flit>>,
    pub reason: ParkReason,
}

impl ParkedSession {
    /// Can this parked session run on a partition with the given layout?
    pub fn fits(&self, kind: RmKind, r: usize, lanes: usize) -> bool {
        self.kind == kind && self.r == r && self.lanes == lanes
    }
}

/// In-memory store of parked sessions, keyed by session id. Shared between
/// the admission path (which dispatches resumes), the partition workers
/// (which park and re-attach), and clients (suspend/ticket collection).
#[derive(Default)]
pub struct SessionStore {
    inner: Mutex<BTreeMap<u64, ParkedSession>>,
}

impl SessionStore {
    pub fn park(&self, p: ParkedSession) {
        self.inner.lock().unwrap().insert(p.id, p);
    }

    pub fn take(&self, id: u64) -> Option<ParkedSession> {
        self.inner.lock().unwrap().remove(&id)
    }

    pub fn contains(&self, id: u64) -> bool {
        self.inner.lock().unwrap().contains_key(&id)
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop a parked session (its client went away); true if one existed.
    pub fn discard(&self, id: u64) -> bool {
        self.inner.lock().unwrap().remove(&id).is_some()
    }

    /// Remove and return the first parked session `pred` accepts (by
    /// ascending session id — oldest ids first).
    pub fn claim_where(&self, pred: impl Fn(&ParkedSession) -> bool) -> Option<ParkedSession> {
        let mut inner = self.inner.lock().unwrap();
        let id = inner.iter().find(|(_, p)| pred(p)).map(|(id, _)| *id)?;
        inner.remove(&id)
    }

    /// Drop every parked session — server shutdown. Releasing the parked
    /// score senders here ends the score streams of clients still draining,
    /// so their `close()`/`suspend()` calls terminate.
    pub fn clear(&self) {
        self.inner.lock().unwrap().clear();
    }

    /// Telemetry view of every parked session, in session-id order — the
    /// operator plane's `/state` reads this; nothing is claimed or mutated.
    pub fn summaries(&self) -> Vec<ParkedSummary> {
        self.inner
            .lock()
            .unwrap()
            .values()
            .map(|p| ParkedSummary {
                id: p.id,
                reason: p.reason,
                flits: p.flits,
                samples: p.samples,
                live: p.inbox.is_some(),
                queued_flits: p.inbox.as_ref().map_or(0, |i| i.probe().queued),
            })
            .collect()
    }
}

/// One parked session's telemetry row (see [`SessionStore::summaries`]).
#[derive(Clone, Copy, Debug)]
pub struct ParkedSummary {
    pub id: u64,
    pub reason: ParkReason,
    /// Input flits processed before the park.
    pub flits: u64,
    /// Valid samples scored before the park.
    pub samples: u64,
    /// True for a transparent park (live inbox retained — the session
    /// re-attaches when its inbox stirs).
    pub live: bool,
    /// Flits queued behind a live parked session's inbox.
    pub queued_flits: usize,
}

/// A suspended session serialized for transport: everything a fresh
/// `FabricServer` (same config) needs to resume the stream bit-identically,
/// including the client-side cursor (`seq`/`pushed`) and the pending tail
/// of samples that had not yet filled a chunk.
///
/// Wire format: `"FSTK" | u8 version | u32 payload_len | payload | u32 crc`
/// with the CRC-32 (IEEE) taken over the payload — a truncated or corrupted
/// ticket is refused with a named error before any field is trusted.
#[derive(Clone, Debug, PartialEq)]
pub struct SessionTicket {
    pub id: u64,
    pub kind: RmKind,
    pub r: usize,
    pub lanes: usize,
    pub d: usize,
    pub seed: u64,
    /// Worker cursor: input flits fully processed.
    pub flits: u64,
    /// Worker cursor: valid samples scored.
    pub samples: u64,
    /// Client cursor: next flit sequence number.
    pub seq: u64,
    /// Client cursor: samples pushed so far.
    pub pushed: u64,
    /// Pending tail: samples staged client-side, short of a full chunk.
    pub staged: Vec<f32>,
    pub warmup: Vec<f32>,
    pub snapshot: Option<Vec<u8>>,
}

fn put_kind(w: &mut Writer, kind: RmKind) {
    match kind {
        RmKind::Empty => w.put_u8(0),
        RmKind::Bypass => w.put_u8(1),
        RmKind::Detector(k) => {
            w.put_u8(2);
            let idx = DetectorKind::ALL.iter().position(|&a| a == k).unwrap_or(0);
            w.put_u8(idx as u8);
        }
    }
}

fn get_kind(r: &mut Reader<'_>) -> Result<RmKind> {
    Ok(match r.get_u8()? {
        0 => RmKind::Empty,
        1 => RmKind::Bypass,
        2 => {
            let idx = r.get_u8()? as usize;
            let Some(&k) = DetectorKind::ALL.get(idx) else {
                bail!("ticket names unknown detector index {idx}");
            };
            RmKind::Detector(k)
        }
        other => bail!("ticket has unknown RM kind tag {other}"),
    })
}

fn put_f32_vec(w: &mut Writer, vs: &[f32]) {
    w.put_u32(vs.len() as u32);
    for &v in vs {
        w.put_f32(v);
    }
}

fn get_f32_vec(r: &mut Reader<'_>) -> Result<Vec<f32>> {
    let n = r.get_u32()? as usize;
    let mut out = Vec::with_capacity(n.min(1 << 20));
    for _ in 0..n {
        out.push(r.get_f32()?);
    }
    Ok(out)
}

impl SessionTicket {
    /// Serialize to the CRC-framed wire format.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut p = Writer::new();
        p.put_u64(self.id);
        put_kind(&mut p, self.kind);
        p.put_u32(self.r as u32);
        p.put_u32(self.lanes as u32);
        p.put_u32(self.d as u32);
        p.put_u64(self.seed);
        p.put_u64(self.flits);
        p.put_u64(self.samples);
        p.put_u64(self.seq);
        p.put_u64(self.pushed);
        put_f32_vec(&mut p, &self.staged);
        put_f32_vec(&mut p, &self.warmup);
        match &self.snapshot {
            Some(bytes) => {
                p.put_u8(1);
                p.put_u32(bytes.len() as u32);
                p.buf.extend_from_slice(bytes);
            }
            None => p.put_u8(0),
        }
        let mut w = Writer::new();
        w.buf.extend_from_slice(&TICKET_MAGIC);
        w.put_u8(TICKET_VERSION);
        w.put_u32(p.buf.len() as u32);
        let crc = crc32(&p.buf);
        w.buf.extend_from_slice(&p.buf);
        w.put_u32(crc);
        w.buf
    }

    /// Parse and validate a ticket; refuses truncation, trailing bytes and
    /// CRC mismatches with named errors, never panics.
    pub fn from_bytes(bytes: &[u8]) -> Result<SessionTicket> {
        let mut r = Reader::new(bytes);
        if r.take(4)? != TICKET_MAGIC {
            return Err(TicketError::NotATicket.into());
        }
        let version = r.get_u8()?;
        if version != TICKET_VERSION {
            return Err(TicketError::Version { got: version, want: TICKET_VERSION }.into());
        }
        let len = r.get_u32()? as usize;
        let payload = r.take(len)?;
        let stored = r.get_u32()?;
        if !r.done() {
            bail!("ticket has trailing bytes — corrupt or from a different layout");
        }
        if crc32(payload) != stored {
            bail!("ticket payload fails its CRC — corrupt");
        }
        let mut p = Reader::new(payload);
        let id = p.get_u64()?;
        let kind = get_kind(&mut p)?;
        let r_ = p.get_u32()? as usize;
        let lanes = p.get_u32()? as usize;
        let d = p.get_u32()? as usize;
        let seed = p.get_u64()?;
        let flits = p.get_u64()?;
        let samples = p.get_u64()?;
        let seq = p.get_u64()?;
        let pushed = p.get_u64()?;
        let staged = get_f32_vec(&mut p)?;
        let warmup = get_f32_vec(&mut p)?;
        let snapshot = match p.get_u8()? {
            0 => None,
            1 => {
                let n = p.get_u32()? as usize;
                Some(p.take(n)?.to_vec())
            }
            other => bail!("ticket has unknown snapshot presence tag {other}"),
        };
        if !p.done() {
            bail!("ticket payload has trailing bytes — length header disagrees");
        }
        Ok(SessionTicket {
            id,
            kind,
            r: r_,
            lanes,
            d,
            seed,
            flits,
            samples,
            seq,
            pushed,
            staged,
            warmup,
            snapshot,
        })
    }

    /// Build the worker half of a resume job from this ticket (no live
    /// channels — the resume path creates fresh ones).
    pub fn to_parked(&self) -> ParkedSession {
        ParkedSession {
            id: self.id,
            kind: self.kind,
            r: self.r,
            lanes: self.lanes,
            d: self.d,
            seed: self.seed,
            warmup: Arc::new(self.warmup.clone()),
            snapshot: self.snapshot.clone(),
            flits: self.flits,
            samples: self.samples,
            inbox: None,
            scores: None,
            reason: ParkReason::Suspend,
        }
    }

    /// Path a spilled ticket lives at inside `dir`.
    pub fn spill_path(dir: &Path, id: u64) -> PathBuf {
        dir.join(format!("session-{id}.fstk"))
    }

    /// Spill the ticket to `dir` (created if missing); returns the path.
    pub fn spill(&self, dir: &Path) -> Result<PathBuf> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating spill dir {}", dir.display()))?;
        let path = Self::spill_path(dir, self.id);
        std::fs::write(&path, self.to_bytes())
            .with_context(|| format!("spilling ticket to {}", path.display()))?;
        Ok(path)
    }

    /// Load a spilled ticket back from `dir`.
    pub fn load(dir: &Path, id: u64) -> Result<SessionTicket> {
        let path = Self::spill_path(dir, id);
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading spilled ticket {}", path.display()))?;
        Self::from_bytes(&bytes).with_context(|| format!("parsing {}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ticket() -> SessionTicket {
        SessionTicket {
            id: 42,
            kind: RmKind::Detector(DetectorKind::RsHash),
            r: 4,
            lanes: 2,
            d: 3,
            seed: 0xDEAD_BEEF,
            flits: 17,
            samples: 1088,
            seq: 17,
            pushed: 1091,
            staged: vec![0.5, -1.5, 2.25],
            warmup: (0..30).map(|i| i as f32 * 0.1).collect(),
            snapshot: Some(vec![1, 2, 3, 4, 5]),
        }
    }

    #[test]
    fn ticket_roundtrips_through_bytes() {
        let t = ticket();
        let bytes = t.to_bytes();
        assert_eq!(SessionTicket::from_bytes(&bytes).unwrap(), t);
        // No-snapshot and non-detector variants too.
        let mut t2 = ticket();
        t2.snapshot = None;
        t2.kind = RmKind::Bypass;
        t2.staged.clear();
        assert_eq!(SessionTicket::from_bytes(&t2.to_bytes()).unwrap(), t2);
    }

    #[test]
    fn corrupt_or_truncated_tickets_are_refused() {
        let bytes = ticket().to_bytes();
        for cut in 0..bytes.len() {
            assert!(
                SessionTicket::from_bytes(&bytes[..cut]).is_err(),
                "cut at {cut} must fail"
            );
        }
        let mut bad_magic = bytes.clone();
        bad_magic[0] ^= 0xFF;
        assert!(SessionTicket::from_bytes(&bad_magic).is_err());
        let mut bad_version = bytes.clone();
        bad_version[4] = 99;
        assert!(SessionTicket::from_bytes(&bad_version).is_err());
        let mut trailing = bytes.clone();
        trailing.push(0);
        assert!(SessionTicket::from_bytes(&trailing).is_err());
        // Any single payload byte flip must trip the CRC.
        for idx in [9, 17, 20, bytes.len() - 5] {
            let mut flipped = bytes.clone();
            flipped[idx] ^= 0x55;
            assert!(SessionTicket::from_bytes(&flipped).is_err(), "flip at {idx} must fail");
        }
    }

    #[test]
    fn magic_and_version_failures_are_typed() {
        let bytes = ticket().to_bytes();
        let mut bad_magic = bytes.clone();
        bad_magic[0] ^= 0xFF;
        let err = SessionTicket::from_bytes(&bad_magic).unwrap_err();
        assert_eq!(err.downcast_ref::<TicketError>(), Some(&TicketError::NotATicket));
        // The version byte sits outside the CRC frame, so a mismatched
        // version from a future build is caught as *version*, not garbage.
        let mut bad_version = bytes.clone();
        bad_version[4] = 99;
        let err = SessionTicket::from_bytes(&bad_version).unwrap_err();
        assert_eq!(
            err.downcast_ref::<TicketError>(),
            Some(&TicketError::Version { got: 99, want: TICKET_VERSION })
        );
    }

    #[test]
    fn store_parks_takes_and_claims_by_layout() {
        let store = SessionStore::default();
        let park = |id: u64, r: usize| ParkedSession {
            id,
            kind: RmKind::Detector(DetectorKind::Loda),
            r,
            lanes: 1,
            d: 2,
            seed: 1,
            warmup: Arc::new(vec![]),
            snapshot: None,
            flits: 0,
            samples: 0,
            inbox: None,
            scores: None,
            reason: ParkReason::Idle,
        };
        store.park(park(5, 2));
        store.park(park(3, 4));
        assert_eq!(store.len(), 2);
        assert!(store.contains(5));
        let claimed = store
            .claim_where(|p| p.fits(RmKind::Detector(DetectorKind::Loda), 4, 1))
            .expect("r=4 entry must match");
        assert_eq!(claimed.id, 3);
        assert!(store.claim_where(|p| p.r == 4).is_none());
        assert!(store.discard(5));
        assert!(!store.discard(5));
        assert!(store.is_empty());
    }

    #[test]
    fn tickets_spill_to_disk_and_load_back() {
        let dir = std::env::temp_dir().join(format!("fsead-spill-{}", std::process::id()));
        let t = ticket();
        let path = t.spill(&dir).unwrap();
        assert!(path.exists());
        assert_eq!(SessionTicket::load(&dir, t.id).unwrap(), t);
        assert!(SessionTicket::load(&dir, 999).is_err());
        std::fs::remove_file(path).unwrap();
    }
}
