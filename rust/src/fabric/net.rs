//! Network serving plane: the `fsead net` wire protocol over a running
//! [`FabricServer`].
//!
//! The paper's AXI switch composes detector pblocks into ensembles on one
//! device; this module composes them across the wire. A [`NetServer`] is a
//! TCP listener speaking a length-prefixed binary frame protocol mapped
//! 1:1 onto the session API ([`FabricServer::open`] /
//! [`super::server::Session::push`] / `close` / `suspend` /
//! [`FabricServer::resume`]), hand-rolled over `std::net` threads like the
//! operator plane — no async runtime, no serde.
//!
//! # Frame layout
//!
//! Every frame, both directions, is
//!
//! ```text
//! [u8 tag] [u32 len LE] [payload: len bytes]
//! ```
//!
//! with `len` capped at [`MAX_FRAME_PAYLOAD`]. Client frames:
//!
//! | tag                  | payload                                                      |
//! |----------------------|--------------------------------------------------------------|
//! | [`TAG_OPEN`] 0x01    | `u32 d \| u32 pblock (0 = any) \| u32 warmup_len \| f32×warmup_len LE` |
//! | [`TAG_PUSH`] 0x02    | `u64 session \| f32×n LE` — the sample block **verbatim**    |
//! | [`TAG_CLOSE`] 0x03   | `u64 session`                                                |
//! | [`TAG_SUSPEND`] 0x04 | `u64 session`                                                |
//! | [`TAG_RESUME`] 0x05  | [`super::session_store::SessionTicket`] bytes verbatim       |
//!
//! Server frames:
//!
//! | tag                    | payload                                                       |
//! |------------------------|---------------------------------------------------------------|
//! | [`TAG_OPENED`] 0x81    | `u64 session \| u32 pblock`                                   |
//! | [`TAG_SCORES`] 0x82    | `u64 session \| f32×n LE`                                     |
//! | [`TAG_CLOSED`] 0x83    | `u64 session \| u64 samples \| u64 flits \| u8 padded_tail \| u32 tail_valid` |
//! | [`TAG_SUSPENDED`] 0x84 | `u64 session \| ticket bytes`                                 |
//! | [`TAG_RESUMED`] 0x85   | `u64 session \| u32 pblock`                                   |
//! | [`TAG_STATUS`] 0x8F    | `u16 code \| u32 msg_len \| msg (UTF-8)`                      |
//!
//! # Determinism
//!
//! Every client frame gets a deterministic reply, so the connection needs
//! no second thread and no reply reordering: `Open` → `Opened`, `Push` →
//! exactly one `Scores`, `Close` → `Scores` then `Closed`, `Suspend` →
//! `Scores` then `Suspended`, `Resume` → `Resumed`; any failure → one
//! `Status`. In lock-step mode (no drop-policy dark windows — the same
//! predicate the synthetic-load driver uses) the `Scores` reply to a
//! `Push` blocks for every score flit the pushed samples are owed; with
//! swaps or the adaptive controller armed it carries whatever has arrived
//! (possibly nothing), since a drop-policy dark window may legitimately
//! delete flits.
//!
//! # Zero-copy and backpressure
//!
//! A `Push` body is the f32 block verbatim: the samples are decoded from
//! the socket buffer straight into their flit allocations by
//! [`super::server::Session::push_bytes`] — the same single copy the
//! input DMA pays. The bounded `SessionInbox` maps onto the connection's
//! socket reads: a full inbox blocks `push_bytes`, which stalls this
//! handler, which stops reading this socket, which fills this client's
//! TCP window — a slow client throttles only itself, never a partition.
//!
//! # Ticket portability
//!
//! `Suspend` returns the session's ticket bytes over the wire; `Resume`
//! accepts them on any server built from the same config — including a
//! different process on a different machine. Admission refusals
//! ([`AdmitError`]) map onto status codes 1–4 so remote clients can back
//! off and retry exactly like in-process ones.

use std::io::{BufReader, BufWriter, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{Context, Result};

use super::message::encode_f32_le;
use super::server::{AdmitError, FabricServer, ServeError, Session, SessionSpec};
use super::session_store::SessionTicket;
use crate::config::DarkPolicy;

// ---------------------------------------------------------------------------
// Wire constants
// ---------------------------------------------------------------------------

/// Frame payload cap (16 MiB) — same bound as the score sink's frames; a
/// torn or hostile length word never makes the server allocate gigabytes.
pub const MAX_FRAME_PAYLOAD: usize = 16 << 20;

/// Client → server: open a session (`u32 d | u32 pblock | u32 warmup_len |
/// f32×warmup_len`); `pblock` 0 lets admission pick any fitting partition.
pub const TAG_OPEN: u8 = 0x01;
/// Client → server: stream samples (`u64 session | f32×n LE`).
pub const TAG_PUSH: u8 = 0x02;
/// Client → server: TLAST flush + teardown (`u64 session`).
pub const TAG_CLOSE: u8 = 0x03;
/// Client → server: checkpoint into a portable ticket (`u64 session`).
pub const TAG_SUSPEND: u8 = 0x04;
/// Client → server: resume from ticket bytes (the payload *is* the ticket).
pub const TAG_RESUME: u8 = 0x05;
/// Client → server: liveness probe (empty payload, no session needed) —
/// answered with one [`TAG_PONG`]. The router's health loop uses this.
pub const TAG_PING: u8 = 0x06;

/// Server → client: session opened (`u64 session | u32 pblock`).
pub const TAG_OPENED: u8 = 0x81;
/// Server → client: scores (`u64 session | f32×n LE`).
pub const TAG_SCORES: u8 = 0x82;
/// Server → client: session closed
/// (`u64 session | u64 samples | u64 flits | u8 padded_tail | u32 tail_valid`).
pub const TAG_CLOSED: u8 = 0x83;
/// Server → client: session suspended (`u64 session | ticket bytes`).
pub const TAG_SUSPENDED: u8 = 0x84;
/// Server → client: session resumed (`u64 session | u32 pblock`).
pub const TAG_RESUMED: u8 = 0x85;
/// Server → client: liveness reply to [`TAG_PING`] (empty payload).
pub const TAG_PONG: u8 = 0x86;
/// Server → client: typed failure (`u16 code | u32 msg_len | msg`).
///
/// Codes in [`STATUS_NOTICE_MIN`]`..=`[`STATUS_NOTICE_MAX`] are
/// *informational*: the router emits them **before** the real reply frame
/// (e.g. `rerouted` ahead of the `Scores` a recovered push is owed) and a
/// conforming client records them and keeps reading.
pub const TAG_STATUS: u8 = 0x8F;

/// [`AdmitError::Saturated`] — overload shedding; back off and retry.
pub const STATUS_SATURATED: u16 = 1;
/// [`AdmitError::Timeout`] — `open_timeout_ms` elapsed waiting for a slot.
pub const STATUS_TIMEOUT: u16 = 2;
/// [`AdmitError::QueueFull`] — `max_waiters` clients already queued.
pub const STATUS_QUEUE_FULL: u16 = 3;
/// [`AdmitError::ShuttingDown`] — the server is going away.
pub const STATUS_SHUTTING_DOWN: u16 = 4;
/// Malformed frame: truncated payload, short header, mid-frame disconnect.
pub const STATUS_BAD_FRAME: u16 = 10;
/// Declared frame length over [`MAX_FRAME_PAYLOAD`].
pub const STATUS_FRAME_TOO_LARGE: u16 = 11;
/// Unknown frame tag.
pub const STATUS_UNKNOWN_TAG: u16 = 12;
/// No session is open on this connection (or the id does not match it).
pub const STATUS_NO_SESSION: u16 = 13;
/// A session is already open on this connection.
pub const STATUS_SESSION_OPEN: u16 = 14;
/// The `Resume` payload does not parse as a session ticket.
pub const STATUS_BAD_TICKET: u16 = 15;
/// The server refused the resume (layout mismatch, duplicate, busy).
pub const STATUS_RESUME_REFUSED: u16 = 16;
/// Concurrent-connection cap reached; shed before a handler was spawned.
pub const STATUS_SERVER_BUSY: u16 = 17;
/// The session's service failed ([`ServeError`] — the detail names the code).
pub const STATUS_SERVE_FAILED: u16 = 18;
/// The server refused the open for non-admission reasons (d = 0, warmup
/// not a whole number of rows, unknown pblock).
pub const STATUS_OPEN_REFUSED: u16 = 19;
/// Router notice: the session was moved to another worker (drain,
/// re-shard or crash recovery). Informational — the real reply follows.
pub const STATUS_REROUTED: u16 = 20;
/// Router: the session's worker died and no healthy worker could absorb
/// it — the session is gone. Terminal for the session, not the connection.
pub const STATUS_WORKER_LOST: u16 = 21;
/// Router notice: the session was recovered from its last checkpoint but
/// some post-checkpoint samples could not be replayed — the message names
/// the bounded loss. Informational — the real reply follows.
pub const STATUS_RESUME_GAP: u16 = 22;
/// The `Resume` ticket parses but was written by an incompatible ticket
/// layout version ([`super::session_store::TICKET_VERSION`]).
pub const STATUS_TICKET_VERSION: u16 = 23;
/// The `Resume` ticket is valid but no served partition matches its
/// layout (RM kind / r / lanes) — the worker is mis-provisioned for it.
pub const STATUS_CONFIG_MISMATCH: u16 = 24;

/// Lowest informational (notice) status code — see [`TAG_STATUS`].
pub const STATUS_NOTICE_MIN: u16 = 20;
/// Highest informational (notice) status code. `worker_lost` (21) is
/// deliberately *outside* the notice range: it terminates the session and
/// arrives instead of a reply, not ahead of one.
pub const STATUS_NOTICE_MAX: u16 = 29;

/// Is `code` an informational router notice (precedes the real reply)
/// rather than a refusal that replaces it?
pub fn is_notice(code: u16) -> bool {
    (STATUS_NOTICE_MIN..=STATUS_NOTICE_MAX).contains(&code) && code != STATUS_WORKER_LOST
}

// ---------------------------------------------------------------------------
// Typed protocol errors
// ---------------------------------------------------------------------------

/// Everything the protocol layer can refuse, each with a stable status
/// code — [`AdmitError`] and [`ServeError`] lifted onto the wire plus the
/// framing failures only a network front end can have.
#[derive(Clone, Debug, PartialEq)]
pub enum NetError {
    /// Truncated/garbled frame or a disconnect inside one.
    BadFrame(String),
    FrameTooLarge { len: usize },
    UnknownTag(u8),
    NoSession,
    SessionOpen,
    BadTicket(String),
    ResumeRefused(String),
    ServerBusy,
    /// Session service failed; `code` is [`ServeError::code`].
    ServeFailed { code: String, detail: String },
    OpenRefused(String),
    Admit(AdmitError),
    /// Router notice: the session now lives on another worker.
    Rerouted(String),
    /// Router: the session could not be re-homed — no healthy worker.
    WorkerLost(String),
    /// Router notice: recovered from checkpoint with bounded sample loss.
    ResumeGap(String),
    /// The resume ticket's layout version does not match this build.
    TicketVersion { got: u8, want: u8 },
    /// The resume ticket fits no served partition layout.
    ConfigMismatch(String),
}

impl NetError {
    /// The wire status code for this error.
    pub fn code(&self) -> u16 {
        match self {
            NetError::Admit(AdmitError::Saturated) => STATUS_SATURATED,
            NetError::Admit(AdmitError::Timeout { .. }) => STATUS_TIMEOUT,
            NetError::Admit(AdmitError::QueueFull { .. }) => STATUS_QUEUE_FULL,
            NetError::Admit(AdmitError::ShuttingDown) => STATUS_SHUTTING_DOWN,
            NetError::BadFrame(_) => STATUS_BAD_FRAME,
            NetError::FrameTooLarge { .. } => STATUS_FRAME_TOO_LARGE,
            NetError::UnknownTag(_) => STATUS_UNKNOWN_TAG,
            NetError::NoSession => STATUS_NO_SESSION,
            NetError::SessionOpen => STATUS_SESSION_OPEN,
            NetError::BadTicket(_) => STATUS_BAD_TICKET,
            NetError::ResumeRefused(_) => STATUS_RESUME_REFUSED,
            NetError::ServerBusy => STATUS_SERVER_BUSY,
            NetError::ServeFailed { .. } => STATUS_SERVE_FAILED,
            NetError::OpenRefused(_) => STATUS_OPEN_REFUSED,
            NetError::Rerouted(_) => STATUS_REROUTED,
            NetError::WorkerLost(_) => STATUS_WORKER_LOST,
            NetError::ResumeGap(_) => STATUS_RESUME_GAP,
            NetError::TicketVersion { .. } => STATUS_TICKET_VERSION,
            NetError::ConfigMismatch(_) => STATUS_CONFIG_MISMATCH,
        }
    }
}

impl std::fmt::Display for NetError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            NetError::BadFrame(m) => write!(f, "bad frame: {m}"),
            NetError::FrameTooLarge { len } => {
                write!(f, "declared frame length {len} exceeds the {MAX_FRAME_PAYLOAD} cap")
            }
            NetError::UnknownTag(t) => write!(f, "unknown frame tag 0x{t:02x}"),
            NetError::NoSession => write!(f, "no session open on this connection"),
            NetError::SessionOpen => {
                write!(f, "a session is already open on this connection — close it first")
            }
            NetError::BadTicket(m) => write!(f, "bad ticket: {m}"),
            NetError::ResumeRefused(m) => write!(f, "resume refused: {m}"),
            NetError::ServerBusy => {
                write!(f, "too many concurrent connections — retry")
            }
            NetError::ServeFailed { code, detail } => write!(f, "serve failed ({code}): {detail}"),
            NetError::OpenRefused(m) => write!(f, "open refused: {m}"),
            NetError::Admit(e) => write!(f, "{e}"),
            NetError::Rerouted(m) => write!(f, "rerouted: {m}"),
            NetError::WorkerLost(m) => write!(f, "worker lost: {m}"),
            NetError::ResumeGap(m) => write!(f, "resume gap: {m}"),
            NetError::TicketVersion { got, want } => {
                write!(f, "ticket version {got} is not this build's version {want}")
            }
            NetError::ConfigMismatch(m) => write!(f, "config mismatch: {m}"),
        }
    }
}

impl std::error::Error for NetError {}

// ---------------------------------------------------------------------------
// Frame codec (shared with the blocking client)
// ---------------------------------------------------------------------------

/// Read exactly `buf.len()` bytes; `Ok(false)` when EOF arrives first.
fn fill(r: &mut impl Read, buf: &mut [u8]) -> std::io::Result<bool> {
    let mut got = 0;
    while got < buf.len() {
        let n = r.read(&mut buf[got..])?;
        if n == 0 {
            return Ok(false);
        }
        got += n;
    }
    Ok(true)
}

/// Read one frame. `Ok(None)` is a clean hang-up at a frame boundary;
/// a disconnect *inside* a frame or an over-cap length is a typed error.
pub fn read_frame(r: &mut impl Read) -> std::result::Result<Option<(u8, Vec<u8>)>, NetError> {
    let mut tag = [0u8; 1];
    match fill(r, &mut tag) {
        Ok(true) => {}
        Ok(false) => return Ok(None),
        Err(e) => return Err(NetError::BadFrame(format!("reading frame tag: {e}"))),
    }
    let mut len = [0u8; 4];
    match fill(r, &mut len) {
        Ok(true) => {}
        Ok(false) => return Err(NetError::BadFrame("disconnect inside a frame header".into())),
        Err(e) => return Err(NetError::BadFrame(format!("reading frame length: {e}"))),
    }
    let len = u32::from_le_bytes(len) as usize;
    if len > MAX_FRAME_PAYLOAD {
        return Err(NetError::FrameTooLarge { len });
    }
    let mut payload = vec![0u8; len];
    match fill(r, &mut payload) {
        Ok(true) => Ok(Some((tag[0], payload))),
        Ok(false) => Err(NetError::BadFrame("disconnect inside a frame body".into())),
        Err(e) => Err(NetError::BadFrame(format!("reading frame body: {e}"))),
    }
}

/// Write one frame and flush it.
pub fn write_frame(w: &mut impl Write, tag: u8, payload: &[u8]) -> std::io::Result<()> {
    debug_assert!(payload.len() <= MAX_FRAME_PAYLOAD);
    w.write_all(&[tag])?;
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()
}

/// Encode a [`NetError`] as a `Status` payload.
pub fn encode_status(e: &NetError) -> Vec<u8> {
    let msg = e.to_string();
    let mut out = Vec::with_capacity(6 + msg.len());
    out.extend_from_slice(&e.code().to_le_bytes());
    out.extend_from_slice(&(msg.len() as u32).to_le_bytes());
    out.extend_from_slice(msg.as_bytes());
    out
}

/// Decode a `Status` payload into `(code, message)`.
pub fn decode_status(payload: &[u8]) -> std::result::Result<(u16, String), NetError> {
    let mut b = payload;
    let code = u16::from_le_bytes(take(&mut b, 2, "status code")?.try_into().unwrap());
    let len = u32::from_le_bytes(take(&mut b, 4, "status length")?.try_into().unwrap()) as usize;
    let msg = take(&mut b, len, "status message")?;
    Ok((code, String::from_utf8_lossy(msg).into_owned()))
}

fn take<'a>(b: &mut &'a [u8], n: usize, what: &str) -> std::result::Result<&'a [u8], NetError> {
    if b.len() < n {
        return Err(NetError::BadFrame(format!("truncated {what}")));
    }
    let (head, rest) = b.split_at(n);
    *b = rest;
    Ok(head)
}

fn take_u32(b: &mut &[u8], what: &str) -> std::result::Result<u32, NetError> {
    Ok(u32::from_le_bytes(take(b, 4, what)?.try_into().unwrap()))
}

fn take_u64(b: &mut &[u8], what: &str) -> std::result::Result<u64, NetError> {
    Ok(u64::from_le_bytes(take(b, 8, what)?.try_into().unwrap()))
}

// ---------------------------------------------------------------------------
// Listener
// ---------------------------------------------------------------------------

/// How long an accept loop should sleep before retrying after `e`.
///
/// `accept()` errors are never fatal to a listener — a transient refusal
/// must not kill the thread that every future client depends on — but
/// they differ in how hot it is safe to spin: an aborted handshake or an
/// interrupted syscall can be retried immediately, while fd exhaustion
/// (`EMFILE`/`ENFILE`, raw 24/23 on Linux) needs real back-off so the
/// handlers holding those fds get a chance to finish and release them.
/// Shared by the net, operator and router accept loops.
pub fn accept_retry_delay(e: &std::io::Error) -> std::time::Duration {
    use std::io::ErrorKind;
    use std::time::Duration;
    match e.kind() {
        // A client gave up between SYN and accept, or a signal landed:
        // nothing is wrong with the listener, retry at once.
        ErrorKind::ConnectionAborted | ErrorKind::ConnectionReset | ErrorKind::Interrupted => {
            Duration::from_millis(0)
        }
        _ => match e.raw_os_error() {
            // EMFILE (24) / ENFILE (23) / ENOMEM (12): resource pressure —
            // back off long enough for in-flight connections to retire.
            Some(12) | Some(23) | Some(24) => Duration::from_millis(100),
            _ => Duration::from_millis(10),
        },
    }
}

/// Decrements the live-connection gauge when a handler ends, by any path.
struct ConnGuard(Arc<AtomicUsize>);

impl Drop for ConnGuard {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

/// The network plane's TCP listener: one accept thread, one handler
/// thread per connection (a connection is one session's full lifetime, so
/// unlike the operator plane these threads are long-lived), the
/// concurrent count capped by `[fabric.net] max_connections` — over the
/// cap a connection is shed with a `server_busy` status frame before any
/// handler is spawned.
pub struct NetServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
}

impl NetServer {
    /// Bind `addr` (port 0 picks a free port) and serve the frame
    /// protocol over `fabric`, capped at the configured
    /// `[fabric.net] max_connections`.
    pub fn start(addr: &str, fabric: Arc<FabricServer>) -> Result<NetServer> {
        let limit = fabric.config().net.max_connections;
        Self::start_with_limit(addr, fabric, limit)
    }

    /// [`NetServer::start`] with an explicit connection cap.
    pub fn start_with_limit(
        addr: &str,
        fabric: Arc<FabricServer>,
        max_connections: usize,
    ) -> Result<NetServer> {
        let limit = max_connections.max(1);
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding the net listener on {addr}"))?;
        let local = listener.local_addr().context("resolving the net listener address")?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let live = Arc::new(AtomicUsize::new(0));
        let accept = std::thread::Builder::new()
            .name("net".into())
            .spawn(move || loop {
                match listener.accept() {
                    Ok((mut stream, _)) => {
                        if stop2.load(Ordering::SeqCst) {
                            break;
                        }
                        if live.load(Ordering::SeqCst) >= limit {
                            let _ = write_frame(
                                &mut stream,
                                TAG_STATUS,
                                &encode_status(&NetError::ServerBusy),
                            );
                            continue;
                        }
                        live.fetch_add(1, Ordering::SeqCst);
                        let guard = ConnGuard(Arc::clone(&live));
                        let fabric = Arc::clone(&fabric);
                        // If the spawn itself fails, the closure (and the
                        // guard in it) is dropped, keeping the gauge honest.
                        let _ = std::thread::Builder::new().name("net-conn".into()).spawn(
                            move || {
                                let _guard = guard;
                                let _ = serve_connection(stream, &fabric);
                            },
                        );
                    }
                    Err(e) => {
                        if stop2.load(Ordering::SeqCst) {
                            break;
                        }
                        // Transient accept failures (fd exhaustion, aborted
                        // handshakes, EINTR) must not kill the listener.
                        std::thread::sleep(accept_retry_delay(&e));
                    }
                }
            })
            .expect("spawn net accept thread");
        Ok(NetServer { addr: local, stop, accept: Some(accept) })
    }

    /// The bound address (useful with port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting and join the accept thread. Live connections keep
    /// their sessions; they end when their client hangs up or the fabric
    /// shuts down underneath them.
    pub fn stop(mut self) {
        self.stop_impl();
    }

    fn stop_impl(&mut self) {
        if self.accept.is_none() {
            return;
        }
        self.stop.store(true, Ordering::SeqCst);
        // Wake the blocking accept with a throwaway connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.stop_impl();
    }
}

// ---------------------------------------------------------------------------
// Per-connection handler
// ---------------------------------------------------------------------------

/// One connection's session state: at most one live session plus the
/// score-delivery cursor (flits whose scores have been sent back).
struct ConnState {
    session: Option<Session>,
    delivered: u64,
}

fn serve_connection(stream: TcpStream, fabric: &Arc<FabricServer>) -> std::io::Result<()> {
    // Lock-step (block for each pushed flit's score flit) assumes 1:1
    // input→score framing — the same predicate as the synthetic-load
    // driver: a config whose drop-policy dark windows can delete flits
    // must poll instead of blocking on a score that was dropped.
    let dfx = &fabric.config().dfx;
    let lockstep =
        dfx.policy == DarkPolicy::Bypass || (!dfx.adaptive && dfx.swaps.is_empty());
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);
    let mut conn = ConnState { session: None, delivered: 0 };
    loop {
        let (tag, payload) = match read_frame(&mut reader) {
            Ok(Some(f)) => f,
            // Clean hang-up: the session (if any) is dropped below, which
            // force-closes its inbox — an abandoned remote client can
            // never wedge a partition.
            Ok(None) => break,
            Err(e) => {
                // Typed refusal, then drop the connection: after a torn
                // or oversized frame the byte stream is out of sync.
                let _ = write_frame(&mut writer, TAG_STATUS, &encode_status(&e));
                break;
            }
        };
        let outcome = match tag {
            TAG_OPEN => handle_open(&mut conn, fabric, &mut writer, &payload),
            TAG_PUSH => handle_push(&mut conn, lockstep, &mut writer, &payload),
            TAG_CLOSE => handle_close(&mut conn, &mut writer, &payload),
            TAG_SUSPEND => handle_suspend(&mut conn, &mut writer, &payload),
            TAG_RESUME => handle_resume(&mut conn, fabric, &mut writer, &payload),
            // Sessionless liveness probe: one empty Pong, nothing touched.
            TAG_PING => write_frame(&mut writer, TAG_PONG, &[])
                .map_err(|e| NetError::BadFrame(format!("writing pong frame: {e}"))),
            other => Err(NetError::UnknownTag(other)),
        };
        match outcome {
            Ok(()) => {}
            Err(e) => {
                let fatal = matches!(
                    e,
                    NetError::BadFrame(_) | NetError::FrameTooLarge { .. } | NetError::UnknownTag(_)
                );
                if write_frame(&mut writer, TAG_STATUS, &encode_status(&e)).is_err() || fatal {
                    break;
                }
            }
        }
    }
    // Dropping a live session abandons it server-side (inbox force-closed,
    // partition freed) — the teardown path for disconnects mid-session.
    drop(conn.session.take());
    Ok(())
}

/// Map a session-API failure onto a wire status: typed admission errors
/// keep their dedicated codes, typed serve errors carry their code string,
/// anything else is a refusal with the error chain as detail.
fn api_error(err: anyhow::Error, refused: fn(String) -> NetError) -> NetError {
    if let Some(e) = err.downcast_ref::<AdmitError>() {
        return NetError::Admit(e.clone());
    }
    if let Some(e) = err.downcast_ref::<ServeError>() {
        return NetError::ServeFailed { code: e.code().to_string(), detail: format!("{err:#}") };
    }
    refused(format!("{err:#}"))
}

/// Write the `u64 session | u32 pblock` acknowledgement (`Opened` /
/// `Resumed`) for a session that just went live on this connection.
fn write_session_ack(
    writer: &mut impl Write,
    tag: u8,
    id: u64,
    pblock: usize,
) -> std::result::Result<(), NetError> {
    let mut out = Vec::with_capacity(12);
    out.extend_from_slice(&id.to_le_bytes());
    out.extend_from_slice(&(pblock as u32).to_le_bytes());
    write_frame(writer, tag, &out)
        .map_err(|e| NetError::BadFrame(format!("writing session ack frame: {e}")))
}

fn handle_open(
    conn: &mut ConnState,
    fabric: &Arc<FabricServer>,
    writer: &mut impl Write,
    payload: &[u8],
) -> std::result::Result<(), NetError> {
    let mut b = payload;
    let d = take_u32(&mut b, "open d")? as usize;
    let pblock = take_u32(&mut b, "open pblock")? as usize;
    let warmup_len = take_u32(&mut b, "open warmup length")? as usize;
    let warmup_bytes = take(&mut b, warmup_len.saturating_mul(4), "open warmup samples")?;
    if !b.is_empty() {
        return Err(NetError::BadFrame(format!("{} trailing bytes after open", b.len())));
    }
    if conn.session.is_some() {
        return Err(NetError::SessionOpen);
    }
    let mut warmup = Vec::new();
    super::message::decode_f32_le(warmup_bytes, &mut warmup);
    let mut spec = SessionSpec::new(d, warmup);
    if pblock != 0 {
        spec.pblock = Some(pblock);
    }
    let session = fabric.open(spec).map_err(|e| api_error(e, NetError::OpenRefused))?;
    conn.delivered = session.flits_sent();
    let (id, pblock) = (session.id(), session.pblock());
    conn.session = Some(session);
    write_session_ack(writer, TAG_OPENED, id, pblock)
}

fn handle_resume(
    conn: &mut ConnState,
    fabric: &Arc<FabricServer>,
    writer: &mut impl Write,
    payload: &[u8],
) -> std::result::Result<(), NetError> {
    if conn.session.is_some() {
        return Err(NetError::SessionOpen);
    }
    let ticket = SessionTicket::from_bytes(payload).map_err(|e| {
        // A well-formed ticket from an incompatible layout version fails
        // loud with its own code — a router landing on a mis-versioned
        // worker must be able to tell that from wire garbage.
        match e.downcast_ref::<super::session_store::TicketError>() {
            Some(&super::session_store::TicketError::Version { got, want }) => {
                NetError::TicketVersion { got, want }
            }
            _ => NetError::BadTicket(format!("{e:#}")),
        }
    })?;
    let session = fabric.resume(ticket).map_err(|e| {
        if let Some(m) = e.downcast_ref::<super::server::ConfigMismatch>() {
            return NetError::ConfigMismatch(m.to_string());
        }
        api_error(e, NetError::ResumeRefused)
    })?;
    // The score cursor continues from the ticket's flit sequence — scores
    // for earlier flits were already delivered by the suspending server.
    conn.delivered = session.flits_sent();
    let (id, pblock) = (session.id(), session.pblock());
    conn.session = Some(session);
    write_session_ack(writer, TAG_RESUMED, id, pblock)
}

/// The live session on this connection, checked against the frame's id.
fn session_for(conn: &mut ConnState, id: u64) -> std::result::Result<&mut Session, NetError> {
    match conn.session {
        Some(ref mut s) if s.id() == id => Ok(s),
        _ => Err(NetError::NoSession),
    }
}

fn handle_push(
    conn: &mut ConnState,
    lockstep: bool,
    writer: &mut impl Write,
    payload: &[u8],
) -> std::result::Result<(), NetError> {
    let mut b = payload;
    let id = take_u64(&mut b, "push session id")?;
    let delivered = conn.delivered;
    let sent = {
        let session = session_for(conn, id)?;
        let row = 4 * session.dim();
        if row == 0 || b.len() % row != 0 {
            return Err(NetError::BadFrame(format!(
                "push body of {} bytes is not a whole number of {}-byte rows",
                b.len(),
                row
            )));
        }
        session.push_bytes(b).map_err(|err| {
            // The body was row-aligned, so a push failure means the
            // session died server-side (shutdown / partition failure).
            // Keep the dead session so `Close` can fetch its typed
            // outcome; surface the failure now as a status.
            api_error(err, |detail| NetError::ServeFailed { code: "service".into(), detail })
        })?;
        session.flits_sent()
    };
    let scores = {
        let session = session_for(conn, id)?;
        if lockstep {
            let owed = sent.saturating_sub(delivered);
            let mut out = Vec::new();
            for _ in 0..owed {
                match session.recv_scores() {
                    Some(v) => out.extend(v),
                    // Stream ended early: the session is dying (force-close
                    // or shutdown). Deliver what arrived; the client's
                    // `Close` surfaces the typed outcome error.
                    None => break,
                }
            }
            out
        } else {
            session.poll_scores()
        }
    };
    conn.delivered = sent;
    write_scores(writer, id, &scores)
}

fn handle_close(
    conn: &mut ConnState,
    writer: &mut impl Write,
    payload: &[u8],
) -> std::result::Result<(), NetError> {
    let mut b = payload;
    let id = take_u64(&mut b, "close session id")?;
    if !b.is_empty() {
        return Err(NetError::BadFrame(format!("{} trailing bytes after close", b.len())));
    }
    session_for(conn, id)?;
    let session = conn.session.take().expect("checked above");
    conn.delivered = 0;
    let closed = session
        .close()
        .map_err(|e| api_error(e, |detail| NetError::ServeFailed { code: "service".into(), detail }))?;
    write_scores(writer, id, &closed.scores)?;
    let mut out = Vec::with_capacity(8 + 8 + 8 + 1 + 4);
    out.extend_from_slice(&id.to_le_bytes());
    out.extend_from_slice(&closed.samples.to_le_bytes());
    out.extend_from_slice(&closed.flits.to_le_bytes());
    out.push(closed.padded_tail as u8);
    out.extend_from_slice(&(closed.tail_valid as u32).to_le_bytes());
    write_frame(writer, TAG_CLOSED, &out)
        .map_err(|e| NetError::BadFrame(format!("writing closed frame: {e}")))
}

fn handle_suspend(
    conn: &mut ConnState,
    writer: &mut impl Write,
    payload: &[u8],
) -> std::result::Result<(), NetError> {
    let mut b = payload;
    let id = take_u64(&mut b, "suspend session id")?;
    if !b.is_empty() {
        return Err(NetError::BadFrame(format!("{} trailing bytes after suspend", b.len())));
    }
    session_for(conn, id)?;
    let session = conn.session.take().expect("checked above");
    conn.delivered = 0;
    let (ticket, scores) = session
        .suspend()
        .map_err(|e| api_error(e, |detail| NetError::ServeFailed { code: "service".into(), detail }))?;
    write_scores(writer, id, &scores)?;
    let ticket_bytes = ticket.to_bytes();
    let mut out = Vec::with_capacity(8 + ticket_bytes.len());
    out.extend_from_slice(&id.to_le_bytes());
    out.extend_from_slice(&ticket_bytes);
    write_frame(writer, TAG_SUSPENDED, &out)
        .map_err(|e| NetError::BadFrame(format!("writing suspended frame: {e}")))
}

fn write_scores(
    writer: &mut impl Write,
    id: u64,
    scores: &[f32],
) -> std::result::Result<(), NetError> {
    let mut out = Vec::with_capacity(8 + scores.len() * 4);
    out.extend_from_slice(&id.to_le_bytes());
    encode_f32_le(scores, &mut out);
    write_frame(writer, TAG_SCORES, &out)
        .map_err(|e| NetError::BadFrame(format!("writing scores frame: {e}")))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn frame_codec_roundtrips() {
        let mut buf = Vec::new();
        write_frame(&mut buf, TAG_PUSH, b"hello").unwrap();
        write_frame(&mut buf, TAG_CLOSE, b"").unwrap();
        let mut r = Cursor::new(buf);
        assert_eq!(read_frame(&mut r).unwrap(), Some((TAG_PUSH, b"hello".to_vec())));
        assert_eq!(read_frame(&mut r).unwrap(), Some((TAG_CLOSE, Vec::new())));
        assert_eq!(read_frame(&mut r).unwrap(), None, "clean EOF at a frame boundary");
    }

    #[test]
    fn torn_frames_yield_typed_errors_at_every_cut() {
        let mut whole = Vec::new();
        write_frame(&mut whole, TAG_OPEN, &[1, 2, 3, 4, 5, 6, 7]).unwrap();
        // Cutting anywhere inside the frame (after the tag byte) must be a
        // BadFrame, never a panic; cutting at 0 is a clean EOF.
        for cut in 1..whole.len() {
            let mut r = Cursor::new(whole[..cut].to_vec());
            match read_frame(&mut r) {
                Err(NetError::BadFrame(_)) => {}
                other => panic!("cut at {cut}: expected BadFrame, got {other:?}"),
            }
        }
        let mut r = Cursor::new(Vec::<u8>::new());
        assert_eq!(read_frame(&mut r).unwrap(), None);
    }

    #[test]
    fn oversized_declared_length_is_refused_without_allocating() {
        let mut buf = vec![TAG_PUSH];
        buf.extend_from_slice(&(u32::MAX).to_le_bytes());
        let mut r = Cursor::new(buf);
        match read_frame(&mut r) {
            Err(NetError::FrameTooLarge { len }) => assert_eq!(len, u32::MAX as usize),
            other => panic!("expected FrameTooLarge, got {other:?}"),
        }
    }

    #[test]
    fn status_payload_roundtrips() {
        for e in [
            NetError::Admit(AdmitError::Saturated),
            NetError::Admit(AdmitError::Timeout { waited_ms: 250 }),
            NetError::UnknownTag(0x7F),
            NetError::ServeFailed { code: "poisoned".into(), detail: "boom".into() },
        ] {
            let payload = encode_status(&e);
            let (code, msg) = decode_status(&payload).unwrap();
            assert_eq!(code, e.code());
            assert_eq!(msg, e.to_string());
        }
    }

    #[test]
    fn status_codes_are_stable() {
        assert_eq!(NetError::Admit(AdmitError::Saturated).code(), 1);
        assert_eq!(NetError::Admit(AdmitError::ShuttingDown).code(), 4);
        assert_eq!(NetError::BadFrame(String::new()).code(), 10);
        assert_eq!(NetError::ServerBusy.code(), 17);
        assert_eq!(NetError::OpenRefused(String::new()).code(), 19);
        assert_eq!(NetError::Rerouted(String::new()).code(), 20);
        assert_eq!(NetError::WorkerLost(String::new()).code(), 21);
        assert_eq!(NetError::ResumeGap(String::new()).code(), 22);
        assert_eq!(NetError::TicketVersion { got: 9, want: 1 }.code(), 23);
        assert_eq!(NetError::ConfigMismatch(String::new()).code(), 24);
    }

    #[test]
    fn notice_range_excludes_terminal_worker_lost() {
        assert!(is_notice(STATUS_REROUTED));
        assert!(is_notice(STATUS_RESUME_GAP));
        assert!(!is_notice(STATUS_WORKER_LOST), "worker_lost replaces the reply");
        assert!(!is_notice(STATUS_SERVE_FAILED));
        assert!(!is_notice(STATUS_TICKET_VERSION));
        assert!(!is_notice(STATUS_CONFIG_MISMATCH));
    }

    #[test]
    fn accept_errors_classify_into_retry_delays() {
        use std::io::{Error, ErrorKind};
        use std::time::Duration;
        // Aborted handshakes and EINTR: safe to retry immediately.
        for kind in [ErrorKind::ConnectionAborted, ErrorKind::Interrupted] {
            assert_eq!(accept_retry_delay(&Error::from(kind)), Duration::from_millis(0));
        }
        // fd exhaustion (EMFILE/ENFILE) and ENOMEM: long back-off so the
        // handlers holding the fds can retire and release them.
        for raw in [23, 24, 12] {
            assert_eq!(
                accept_retry_delay(&Error::from_raw_os_error(raw)),
                Duration::from_millis(100),
                "raw os error {raw}"
            );
        }
        // Anything else: a short, conservative pause.
        assert_eq!(
            accept_retry_delay(&Error::new(ErrorKind::Other, "?")),
            Duration::from_millis(10)
        );
    }
}
