//! Fault-tolerant session router: `fsead route` / `[fabric.router]`.
//!
//! A [`Router`] is a TCP front end speaking the exact [`super::net`] frame
//! protocol to clients while fanning their sessions out across N
//! downstream `fsead net` worker processes. Placement is consistent
//! hashing on the session id over a [`WorkerPool`] ring, so any router
//! restart (or a second router over the same fleet) computes the same
//! owners.
//!
//! # Robustness model
//!
//! The unit of recovery is the **router-held ticket**: every session is
//! checkpointed (worker-side `Suspend` → ticket → `Resume`, on the same
//! upstream connection) every `checkpoint_pushes` pushes, and the raw
//! samples pushed since the last checkpoint are kept in a bounded replay
//! buffer. When a worker dies mid-stream — connection error, wedged-socket
//! timeout, or the health prober ejecting it — the session's handler
//! resumes the ticket on the next ring candidate, replays the buffered
//! samples in one push, discards the score prefix the client already has,
//! and completes the original request. The client sees a `rerouted`
//! notice (status 20) ahead of the reply it was owed; in lock-step
//! configurations the delivered score suffix is bit-identical to an
//! uninterrupted run, because detector state is carried by the ticket and
//! the replayed samples re-derive exactly the missing scores.
//!
//! Bounded loss is possible only when a single push block exceeds
//! `replay_cap_bytes` (it cannot be buffered) *and* its worker dies before
//! the immediate post-push checkpoint; the gap is then reported honestly
//! as a `resume_gap` notice (status 22) naming the lost rows. A session
//! that no routable worker will absorb within `retry_deadline_ms` is
//! terminated with `worker_lost` (status 21) — terminal for the session,
//! not the connection.
//!
//! Membership changes (worker join via [`Router::add_worker`], graceful
//! leave via [`Router::drain_worker`], prober ejection/revival) bump the
//! pool epoch; each connection handler re-checks its session's ring owner
//! before the next forward and migrates lazily with the same
//! suspend-carry-resume hop, so a join re-shards exactly the hash ranges
//! the ring moves and a drain empties a worker without dropping a sample.
//!
//! With one healthy worker and no faults, none of this machinery fires:
//! the router is bit-transparent to a direct `fsead net` connection
//! (modulo the ids the worker assigns).
//!
//! Workers in one fleet should be provisioned with distinct
//! `[fabric.server] session_id_base` values (`fsead net --session-base`)
//! so ids never collide when tickets move between them.

use std::io::{BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::message::{decode_f32_le, encode_f32_le};
use super::net::{
    accept_retry_delay, encode_status, read_frame, write_frame, NetError, TAG_CLOSE, TAG_CLOSED,
    TAG_OPEN, TAG_OPENED, TAG_PING, TAG_PONG, TAG_PUSH, TAG_RESUME, TAG_RESUMED, TAG_SCORES,
    TAG_STATUS, TAG_SUSPEND, TAG_SUSPENDED,
};
use super::net_client::{NetClient, NetStatus};
use super::session_store::{SessionTicket, TicketError};
use super::worker_pool::{splitmix64, WorkerPool};
use crate::config::RouterCfg;

// ---------------------------------------------------------------------------
// Stats
// ---------------------------------------------------------------------------

/// Router-wide counters, updated by connection handlers and the prober.
#[derive(Default)]
pub struct RouterStats {
    /// Sessions opened through the router (also seeds placement spread).
    pub opened: AtomicU64,
    /// Successful re-homes: crash recoveries plus drain/join migrations.
    pub rerouted: AtomicU64,
    /// Sessions terminated with `worker_lost`.
    pub lost: AtomicU64,
    /// Ticket checkpoints taken.
    pub checkpoints: AtomicU64,
    /// Sample values re-pushed during recoveries.
    pub replayed_values: AtomicU64,
    /// Sample rows reported lost via `resume_gap`.
    pub gap_samples: AtomicU64,
    /// Opens shed because no worker would take them.
    pub sheds: AtomicU64,
    /// Health probes that got their pong.
    pub pings_ok: AtomicU64,
    /// Health probes that failed.
    pub pings_failed: AtomicU64,
    /// Workers ejected from the ring by consecutive failures.
    pub ejections: AtomicU64,
}

/// A plain-value copy of [`RouterStats`] for tests and benches.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RouterSnapshot {
    pub opened: u64,
    pub rerouted: u64,
    pub lost: u64,
    pub checkpoints: u64,
    pub replayed_values: u64,
    pub gap_samples: u64,
    pub sheds: u64,
    pub pings_ok: u64,
    pub pings_failed: u64,
    pub ejections: u64,
}

impl RouterStats {
    pub fn snapshot(&self) -> RouterSnapshot {
        RouterSnapshot {
            opened: self.opened.load(Ordering::SeqCst),
            rerouted: self.rerouted.load(Ordering::SeqCst),
            lost: self.lost.load(Ordering::SeqCst),
            checkpoints: self.checkpoints.load(Ordering::SeqCst),
            replayed_values: self.replayed_values.load(Ordering::SeqCst),
            gap_samples: self.gap_samples.load(Ordering::SeqCst),
            sheds: self.sheds.load(Ordering::SeqCst),
            pings_ok: self.pings_ok.load(Ordering::SeqCst),
            pings_failed: self.pings_failed.load(Ordering::SeqCst),
            ejections: self.ejections.load(Ordering::SeqCst),
        }
    }
}

// ---------------------------------------------------------------------------
// Shared context
// ---------------------------------------------------------------------------

struct Ctx {
    pool: Arc<WorkerPool>,
    stats: Arc<RouterStats>,
    cfg: RouterCfg,
}

fn connect_worker(ctx: &Ctx, addr: &str) -> Result<NetClient> {
    let connect = Duration::from_millis(ctx.cfg.connect_timeout_ms.max(1));
    let mut up = NetClient::connect_timeout(addr, connect)?;
    let io = match ctx.cfg.io_timeout_ms {
        0 => None,
        ms => Some(Duration::from_millis(ms)),
    };
    up.set_io_timeout(io)?;
    Ok(up)
}

/// How a forwarded call failed: a typed refusal from a live worker (pass
/// it through verbatim) vs. a transport failure (the worker is gone —
/// recover).
enum Fail {
    Refused(u16, String),
    Transport(String),
}

fn classify(e: anyhow::Error) -> Fail {
    match e.downcast_ref::<NetStatus>() {
        Some(s) => Fail::Refused(s.code, s.message.clone()),
        None => Fail::Transport(format!("{e:#}")),
    }
}

/// Why a session could not continue: a typed status to forward, or a
/// terminal `worker_lost`.
enum SessionFail {
    Status(u16, String),
    Lost(String),
}

// ---------------------------------------------------------------------------
// Wire helpers (client side of the router)
// ---------------------------------------------------------------------------

fn wr(e: std::io::Error) -> NetError {
    NetError::BadFrame(format!("writing reply frame: {e}"))
}

/// A `Status` payload with an explicit code/message — used to forward a
/// worker's refusal to the client byte-compatibly.
fn raw_status(code: u16, message: &str) -> Vec<u8> {
    let mut out = Vec::with_capacity(6 + message.len());
    out.extend_from_slice(&code.to_le_bytes());
    out.extend_from_slice(&(message.len() as u32).to_le_bytes());
    out.extend_from_slice(message.as_bytes());
    out
}

fn write_status(writer: &mut impl Write, e: &NetError) -> std::result::Result<(), NetError> {
    write_frame(writer, TAG_STATUS, &encode_status(e)).map_err(wr)
}

fn write_session_ack(
    writer: &mut impl Write,
    tag: u8,
    id: u64,
    pblock: u32,
) -> std::result::Result<(), NetError> {
    let mut out = Vec::with_capacity(12);
    out.extend_from_slice(&id.to_le_bytes());
    out.extend_from_slice(&pblock.to_le_bytes());
    write_frame(writer, tag, &out).map_err(wr)
}

fn write_scores(
    writer: &mut impl Write,
    id: u64,
    scores: &[f32],
) -> std::result::Result<(), NetError> {
    let mut out = Vec::with_capacity(8 + scores.len() * 4);
    out.extend_from_slice(&id.to_le_bytes());
    encode_f32_le(scores, &mut out);
    write_frame(writer, TAG_SCORES, &out).map_err(wr)
}

/// Write the terminal status for `fail` and end the session (the caller
/// has already dropped its `Routed`).
fn fail_reply(
    writer: &mut impl Write,
    ctx: &Ctx,
    fail: SessionFail,
) -> std::result::Result<(), NetError> {
    match fail {
        SessionFail::Status(code, msg) => {
            write_frame(writer, TAG_STATUS, &raw_status(code, &msg)).map_err(wr)
        }
        SessionFail::Lost(msg) => {
            ctx.stats.lost.fetch_add(1, Ordering::SeqCst);
            write_status(writer, &NetError::WorkerLost(msg))
        }
    }
}

fn take<'a>(b: &mut &'a [u8], n: usize, what: &str) -> std::result::Result<&'a [u8], NetError> {
    if b.len() < n {
        return Err(NetError::BadFrame(format!("truncated {what}")));
    }
    let (head, rest) = b.split_at(n);
    *b = rest;
    Ok(head)
}

fn take_u32(b: &mut &[u8], what: &str) -> std::result::Result<u32, NetError> {
    Ok(u32::from_le_bytes(take(b, 4, what)?.try_into().unwrap()))
}

fn take_u64(b: &mut &[u8], what: &str) -> std::result::Result<u64, NetError> {
    Ok(u64::from_le_bytes(take(b, 8, what)?.try_into().unwrap()))
}

// ---------------------------------------------------------------------------
// Routed session
// ---------------------------------------------------------------------------

/// One client session as the router tracks it: the live upstream
/// connection, the last checkpoint ticket, and the replay window that
/// makes the ticket recoverable without loss.
struct Routed {
    /// Live worker connection; `Some` from first placement onwards.
    up: Option<NetClient>,
    /// Pool slot of the worker currently serving the session.
    worker: usize,
    id: u64,
    /// Sample dimensionality (row width), for replay-gap accounting and
    /// push alignment checks.
    d: usize,
    pblock: u32,
    /// Last checkpoint ticket — the recovery anchor.
    ticket: Vec<u8>,
    /// Samples pushed since the last checkpoint, concatenated.
    replay: Vec<f32>,
    pushes_since_ckpt: u64,
    /// Score *values obtained from workers* since the last checkpoint —
    /// delivered or pending. Counted at obtain time so a second recovery
    /// before delivery never re-pends duplicates.
    scores_since_ckpt: u64,
    /// Values of the one in-flight push too large for the replay buffer
    /// (0 when none) — lost, and reported, if its worker dies now.
    unreplayable: usize,
    /// Rows confirmed lost, to be reported in the next `resume_gap`.
    gap_samples: u64,
    /// Scores obtained but not yet delivered to the client (checkpoint
    /// drains, recovery replays); prepended to the next scores reply.
    pending: Vec<f32>,
    /// Pool epoch at the last owner check.
    epoch: u64,
}

impl Routed {
    fn key(&self) -> u64 {
        splitmix64(self.id)
    }

    fn live(&mut self) -> &mut NetClient {
        self.up.as_mut().expect("routed session has a live upstream")
    }

    /// Connect + resume the held ticket on the best ring candidate,
    /// replaying the buffered post-checkpoint samples. Returns the fresh
    /// score suffix (the already-delivered prefix is discarded).
    fn place(&mut self, ctx: &Ctx) -> std::result::Result<Vec<f32>, SessionFail> {
        let t0 = Instant::now();
        let deadline = Duration::from_millis(ctx.cfg.retry_deadline_ms.max(1));
        let mut delay = Duration::from_millis(ctx.cfg.backoff_base_ms.max(1));
        let mut last_refusal: Option<(u16, String)> = None;
        loop {
            let mut transport_failures = false;
            for slot in ctx.pool.candidates(self.key()) {
                let addr = ctx.pool.addr_of(slot);
                match self.try_place_on(ctx, slot, &addr) {
                    Ok(fresh) => {
                        ctx.pool.record_success(slot);
                        return Ok(fresh);
                    }
                    // Alive but unwilling (ticket version, config
                    // mismatch, duplicate): not a health event, and the
                    // same ticket cannot succeed there on retry.
                    Err(Fail::Refused(code, msg)) => last_refusal = Some((code, msg)),
                    Err(Fail::Transport(_)) => {
                        transport_failures = true;
                        if ctx.pool.record_failure(slot) {
                            ctx.stats.ejections.fetch_add(1, Ordering::SeqCst);
                        }
                    }
                }
            }
            if !transport_failures {
                // Every routable worker refused outright (or none exist):
                // waiting cannot help.
                return Err(match last_refusal {
                    Some((code, msg)) => SessionFail::Status(code, msg),
                    None => SessionFail::Lost(format!(
                        "no routable worker to re-home session {}",
                        self.id
                    )),
                });
            }
            if t0.elapsed() + delay >= deadline {
                return Err(SessionFail::Lost(format!(
                    "session {}: no worker recovered it within {:?}",
                    self.id,
                    t0.elapsed()
                )));
            }
            std::thread::sleep(delay);
            delay = (delay * 2).min(Duration::from_secs(1));
        }
    }

    fn try_place_on(
        &mut self,
        ctx: &Ctx,
        slot: usize,
        addr: &str,
    ) -> std::result::Result<Vec<f32>, Fail> {
        let mut up = connect_worker(ctx, addr).map_err(|e| Fail::Transport(format!("{e:#}")))?;
        up.resume(&self.ticket).map_err(classify)?;
        let mut got = Vec::new();
        if !self.replay.is_empty() {
            got = up.push(&self.replay).map_err(classify)?;
            ctx.stats.replayed_values.fetch_add(self.replay.len() as u64, Ordering::SeqCst);
        }
        let discard = (self.scores_since_ckpt as usize).min(got.len());
        let fresh = got.split_off(discard);
        // Obtained-since-checkpoint high-water mark: a later recovery of
        // the same window discards everything delivered by this one too.
        self.scores_since_ckpt = self.scores_since_ckpt.max((discard + fresh.len()) as u64);
        self.pblock = up.pblock();
        self.worker = slot;
        self.epoch = ctx.pool.epoch();
        self.up = Some(up);
        Ok(fresh)
    }

    /// Checkpoint in place: suspend on the live connection, keep the
    /// ticket, resume on the same worker. On error the held state is
    /// always consistent for recovery — the ticket/replay pair is updated
    /// *between* the suspend and resume legs.
    fn checkpoint(&mut self, ctx: &Ctx) -> Result<()> {
        let (ticket, scores) = self.live().suspend()?;
        self.pending.extend(scores);
        self.ticket = ticket;
        self.replay.clear();
        self.pushes_since_ckpt = 0;
        self.scores_since_ckpt = 0;
        self.unreplayable = 0;
        // Borrow the field directly so the ticket (a sibling field) can be
        // passed while the upstream is borrowed.
        let up = self.up.as_mut().expect("routed session has a live upstream");
        up.resume(&self.ticket)?;
        self.pblock = up.pblock();
        ctx.stats.checkpoints.fetch_add(1, Ordering::SeqCst);
        Ok(())
    }

    /// The upstream failed (`detail`): account any un-replayable gap,
    /// re-home via the ring, and return the notices the client is owed.
    /// Recovered scores land in `pending`.
    fn recover(
        &mut self,
        ctx: &Ctx,
        detail: &str,
    ) -> std::result::Result<Vec<NetError>, SessionFail> {
        if ctx.pool.record_failure(self.worker) {
            ctx.stats.ejections.fetch_add(1, Ordering::SeqCst);
        }
        let from = ctx.pool.addr_of(self.worker);
        if self.unreplayable > 0 {
            self.gap_samples += (self.unreplayable / self.d.max(1)) as u64;
            self.unreplayable = 0;
        }
        let fresh = self.place(ctx)?;
        self.pending.extend(fresh);
        ctx.stats.rerouted.fetch_add(1, Ordering::SeqCst);
        let mut notices = vec![NetError::Rerouted(format!(
            "session {} re-homed from {} to {}: {detail}",
            self.id,
            from,
            ctx.pool.addr_of(self.worker)
        ))];
        if self.gap_samples > 0 {
            ctx.stats.gap_samples.fetch_add(self.gap_samples, Ordering::SeqCst);
            notices.push(NetError::ResumeGap(format!(
                "session {}: {} sample row(s) since the last checkpoint could not be replayed",
                self.id, self.gap_samples
            )));
            self.gap_samples = 0;
        }
        Ok(notices)
    }

    /// Re-check ring ownership after an epoch change; migrate with a
    /// suspend-carry-resume hop when the session no longer lives on its
    /// owner (worker join re-shard, drain, ejection).
    fn maybe_migrate(&mut self, ctx: &Ctx) -> std::result::Result<Vec<NetError>, SessionFail> {
        let epoch = ctx.pool.epoch();
        if epoch == self.epoch {
            return Ok(Vec::new());
        }
        self.epoch = epoch;
        if ctx.pool.owner(self.key()) == Some(self.worker) && ctx.pool.is_routable(self.worker) {
            return Ok(Vec::new());
        }
        let from = ctx.pool.addr_of(self.worker);
        match self.live().suspend() {
            Ok((ticket, scores)) => {
                // Graceful drain: the fresh ticket carries everything, so
                // the replay window resets and the hop is loss-free.
                self.pending.extend(scores);
                self.ticket = ticket;
                self.replay.clear();
                self.pushes_since_ckpt = 0;
                self.scores_since_ckpt = 0;
                self.unreplayable = 0;
                let fresh = self.place(ctx)?;
                self.pending.extend(fresh);
                ctx.stats.rerouted.fetch_add(1, Ordering::SeqCst);
                Ok(vec![NetError::Rerouted(format!(
                    "session {} drained from {} to {}",
                    self.id,
                    from,
                    ctx.pool.addr_of(self.worker)
                ))])
            }
            Err(e) => {
                // The old worker is gone — crash recovery from the held
                // checkpoint instead of a clean hand-over.
                let detail = match classify(e) {
                    Fail::Refused(_, m) | Fail::Transport(m) => m,
                };
                self.recover(ctx, &detail)
            }
        }
    }

    /// The placement hop right after `Open`: establish the first ticket
    /// and land the session on its ring owner — the same code path as
    /// every later checkpoint, so placement is exercised constantly.
    fn initial_home(&mut self, ctx: &Ctx) -> std::result::Result<(), SessionFail> {
        match self.live().suspend() {
            Ok((ticket, scores)) => {
                self.pending.extend(scores);
                self.ticket = ticket;
            }
            Err(e) => {
                return Err(match classify(e) {
                    Fail::Refused(code, msg) => SessionFail::Status(code, msg),
                    Fail::Transport(detail) => {
                        // No ticket exists yet — nothing to recover from.
                        if ctx.pool.record_failure(self.worker) {
                            ctx.stats.ejections.fetch_add(1, Ordering::SeqCst);
                        }
                        SessionFail::Lost(format!(
                            "session {}: worker died before the first checkpoint: {detail}",
                            self.id
                        ))
                    }
                });
            }
        }
        if ctx.pool.owner(self.key()) == Some(self.worker) {
            // Already home: resume in place on the same connection (field
            // borrow, so the ticket can be passed alongside).
            let up = self.up.as_mut().expect("routed session has a live upstream");
            if up.resume(&self.ticket).is_ok() {
                self.pblock = up.pblock();
                self.epoch = ctx.pool.epoch();
                return Ok(());
            }
            if ctx.pool.record_failure(self.worker) {
                ctx.stats.ejections.fetch_add(1, Ordering::SeqCst);
            }
        }
        self.place(ctx)?;
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Connection handler
// ---------------------------------------------------------------------------

struct RouteState {
    routed: Option<Routed>,
}

/// Keep the pool's per-worker session gauges in sync with where this
/// connection's session actually lives, whatever path moved it.
fn sync_gauge(ctx: &Ctx, gauged: &mut Option<usize>, routed: &Option<Routed>) {
    let now = routed.as_ref().map(|r| r.worker);
    if *gauged != now {
        if let Some(w) = *gauged {
            ctx.pool.session_delta(w, -1);
        }
        if let Some(w) = now {
            ctx.pool.session_delta(w, 1);
        }
        *gauged = now;
    }
}

fn route_connection(stream: TcpStream, ctx: &Ctx) -> std::io::Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);
    let mut st = RouteState { routed: None };
    let mut gauged: Option<usize> = None;
    loop {
        let (tag, payload) = match read_frame(&mut reader) {
            Ok(Some(f)) => f,
            Ok(None) => break,
            Err(e) => {
                let _ = write_frame(&mut writer, TAG_STATUS, &encode_status(&e));
                break;
            }
        };
        let outcome = match tag {
            TAG_OPEN => handle_open(&mut st, ctx, &mut writer, &payload),
            TAG_PUSH => handle_push(&mut st, ctx, &mut writer, &payload),
            TAG_CLOSE => handle_close(&mut st, ctx, &mut writer, &payload),
            TAG_SUSPEND => handle_suspend(&mut st, ctx, &mut writer, &payload),
            TAG_RESUME => handle_resume(&mut st, ctx, &mut writer, &payload),
            TAG_PING => write_frame(&mut writer, TAG_PONG, &[]).map_err(wr),
            other => Err(NetError::UnknownTag(other)),
        };
        sync_gauge(ctx, &mut gauged, &st.routed);
        match outcome {
            Ok(()) => {}
            Err(e) => {
                let fatal = matches!(
                    e,
                    NetError::BadFrame(_) | NetError::FrameTooLarge { .. } | NetError::UnknownTag(_)
                );
                if write_frame(&mut writer, TAG_STATUS, &encode_status(&e)).is_err() || fatal {
                    break;
                }
            }
        }
    }
    // Disconnect: dropping the upstream NetClient closes its TCP stream,
    // and the worker's handler abandons the session — same semantics as a
    // direct client hang-up.
    st.routed = None;
    sync_gauge(ctx, &mut gauged, &st.routed);
    Ok(())
}

fn handle_open(
    st: &mut RouteState,
    ctx: &Ctx,
    writer: &mut impl Write,
    payload: &[u8],
) -> std::result::Result<(), NetError> {
    if st.routed.is_some() {
        return Err(NetError::SessionOpen);
    }
    let mut b = payload;
    let d = take_u32(&mut b, "open d")? as usize;
    let pblock = take_u32(&mut b, "open pblock")? as usize;
    let warmup_len = take_u32(&mut b, "open warmup length")? as usize;
    let warmup_bytes = take(&mut b, warmup_len.saturating_mul(4), "open warmup samples")?;
    if !b.is_empty() {
        return Err(NetError::BadFrame(format!("{} trailing bytes after open", b.len())));
    }
    let mut warmup = Vec::new();
    decode_f32_le(warmup_bytes, &mut warmup);

    // Provisional placement on any healthy worker, spread by the open
    // sequence; the initial checkpoint below re-homes onto the ring
    // owner of the id the worker hands out.
    let seq = ctx.stats.opened.fetch_add(1, Ordering::SeqCst);
    let mut placed: Option<(NetClient, usize)> = None;
    let mut last_refusal: Option<(u16, String)> = None;
    for slot in ctx.pool.candidates(splitmix64(seq ^ 0xA5A5_5A5A_0F0F_F0F0)) {
        let addr = ctx.pool.addr_of(slot);
        let mut up = match connect_worker(ctx, &addr) {
            Ok(u) => u,
            Err(_) => {
                if ctx.pool.record_failure(slot) {
                    ctx.stats.ejections.fetch_add(1, Ordering::SeqCst);
                }
                continue;
            }
        };
        match up.open(d, if pblock == 0 { None } else { Some(pblock) }, &warmup) {
            Ok(_) => {
                ctx.pool.record_success(slot);
                placed = Some((up, slot));
                break;
            }
            Err(e) => match classify(e) {
                // Saturated/refusing but alive — try the next worker.
                Fail::Refused(code, msg) => last_refusal = Some((code, msg)),
                Fail::Transport(_) => {
                    if ctx.pool.record_failure(slot) {
                        ctx.stats.ejections.fetch_add(1, Ordering::SeqCst);
                    }
                }
            },
        }
    }
    let (up, slot) = match placed {
        Some(p) => p,
        None => {
            ctx.stats.sheds.fetch_add(1, Ordering::SeqCst);
            return match last_refusal {
                Some((code, msg)) => {
                    write_frame(writer, TAG_STATUS, &raw_status(code, &msg)).map_err(wr)
                }
                None => fail_reply(
                    writer,
                    ctx,
                    SessionFail::Lost("no healthy worker to place the session on".into()),
                ),
            };
        }
    };
    let id = up.session().expect("open succeeded");
    let pblock = up.pblock();
    let mut routed = Routed {
        up: Some(up),
        worker: slot,
        id,
        d,
        pblock,
        ticket: Vec::new(),
        replay: Vec::new(),
        pushes_since_ckpt: 0,
        scores_since_ckpt: 0,
        unreplayable: 0,
        gap_samples: 0,
        pending: Vec::new(),
        epoch: ctx.pool.epoch(),
    };
    if let Err(fail) = routed.initial_home(ctx) {
        return fail_reply(writer, ctx, fail);
    }
    write_session_ack(writer, TAG_OPENED, routed.id, routed.pblock)?;
    st.routed = Some(routed);
    Ok(())
}

fn handle_resume(
    st: &mut RouteState,
    ctx: &Ctx,
    writer: &mut impl Write,
    payload: &[u8],
) -> std::result::Result<(), NetError> {
    if st.routed.is_some() {
        return Err(NetError::SessionOpen);
    }
    // Parse router-side first: garbage and version skew are refused here
    // with their typed codes without bothering any worker.
    let ticket = SessionTicket::from_bytes(payload).map_err(|e| {
        match e.downcast_ref::<TicketError>() {
            Some(&TicketError::Version { got, want }) => NetError::TicketVersion { got, want },
            _ => NetError::BadTicket(format!("{e:#}")),
        }
    })?;
    let mut routed = Routed {
        up: None,
        worker: 0,
        id: ticket.id,
        d: ticket.d,
        pblock: 0,
        ticket: payload.to_vec(),
        replay: Vec::new(),
        pushes_since_ckpt: 0,
        scores_since_ckpt: 0,
        unreplayable: 0,
        gap_samples: 0,
        pending: Vec::new(),
        epoch: 0,
    };
    if let Err(fail) = routed.place(ctx) {
        return fail_reply(writer, ctx, fail);
    }
    write_session_ack(writer, TAG_RESUMED, routed.id, routed.pblock)?;
    st.routed = Some(routed);
    Ok(())
}

/// Take the routed session out of `st` if `id` names it — callers put it
/// back on the paths where it survives.
fn claim(st: &mut RouteState, id: u64) -> std::result::Result<Routed, NetError> {
    if st.routed.as_ref().map(|r| r.id == id) != Some(true) {
        return Err(NetError::NoSession);
    }
    Ok(st.routed.take().expect("checked above"))
}

fn handle_push(
    st: &mut RouteState,
    ctx: &Ctx,
    writer: &mut impl Write,
    payload: &[u8],
) -> std::result::Result<(), NetError> {
    let mut b = payload;
    let id = take_u64(&mut b, "push session id")?;
    let mut routed = claim(st, id)?;
    let row = 4 * routed.d;
    if row == 0 || b.len() % row != 0 {
        st.routed = Some(routed);
        return Err(NetError::BadFrame(format!(
            "push body of {} bytes is not a whole number of {}-byte rows",
            b.len(),
            row
        )));
    }
    let mut block = Vec::new();
    decode_f32_le(b, &mut block);

    let mut notices = match routed.maybe_migrate(ctx) {
        Ok(n) => n,
        Err(fail) => return fail_reply(writer, ctx, fail),
    };

    // Replay-window upkeep: flush by checkpointing rather than silently
    // overflowing; a block too large to ever buffer is marked so a crash
    // during it is reported as a gap instead of hidden.
    let cap = ctx.cfg.replay_cap_bytes.max(1);
    if !routed.replay.is_empty() && 4 * (routed.replay.len() + block.len()) > cap {
        if routed.checkpoint(ctx).is_err() {
            match routed.recover(ctx, "worker failed during a replay-window checkpoint") {
                Ok(n) => notices.extend(n),
                Err(fail) => return fail_reply(writer, ctx, fail),
            }
        }
    }
    if 4 * (routed.replay.len() + block.len()) <= cap {
        routed.replay.extend_from_slice(&block);
    } else {
        routed.unreplayable = block.len();
    }

    let scores = match routed.live().push(&block) {
        Ok(s) => {
            routed.scores_since_ckpt += s.len() as u64;
            s
        }
        Err(e) => match classify(e) {
            Fail::Refused(code, msg) => {
                // The worker is alive and refused — pass it through and
                // keep the session for a typed `Close`.
                for n in &notices {
                    write_status(writer, n)?;
                }
                st.routed = Some(routed);
                return write_frame(writer, TAG_STATUS, &raw_status(code, &msg)).map_err(wr);
            }
            Fail::Transport(detail) => match routed.recover(ctx, &detail) {
                // Recovery replayed the window; the fresh scores (this
                // push's included) are in `pending`.
                Ok(n) => {
                    notices.extend(n);
                    Vec::new()
                }
                Err(fail) => return fail_reply(writer, ctx, fail),
            },
        },
    };
    routed.pushes_since_ckpt += 1;

    for n in &notices {
        write_status(writer, n)?;
    }
    let mut out = std::mem::take(&mut routed.pending);
    out.extend(scores);
    write_scores(writer, id, &out)?;

    // Checkpoint cadence — and *immediately* after an un-buffered push,
    // so the ticket never trails a sample the replay window is missing.
    if routed.unreplayable > 0 || routed.pushes_since_ckpt >= ctx.cfg.checkpoint_pushes.max(1) {
        if routed.checkpoint(ctx).is_err() {
            match routed.recover(ctx, "worker failed during a checkpoint") {
                Ok(n) => {
                    // The reply is already out; these notices precede the
                    // next one on the wire, which is where the client's
                    // reader collects them.
                    for notice in &n {
                        write_status(writer, notice)?;
                    }
                }
                Err(fail) => return fail_reply(writer, ctx, fail),
            }
        }
    }
    st.routed = Some(routed);
    Ok(())
}

fn handle_close(
    st: &mut RouteState,
    ctx: &Ctx,
    writer: &mut impl Write,
    payload: &[u8],
) -> std::result::Result<(), NetError> {
    let mut b = payload;
    let id = take_u64(&mut b, "close session id")?;
    if !b.is_empty() {
        return Err(NetError::BadFrame(format!("{} trailing bytes after close", b.len())));
    }
    let mut routed = claim(st, id)?;
    let mut notices = match routed.maybe_migrate(ctx) {
        Ok(n) => n,
        Err(fail) => return fail_reply(writer, ctx, fail),
    };
    let mut attempts = 0u32;
    let closed = loop {
        match routed.live().close() {
            Ok(c) => break c,
            Err(e) => match classify(e) {
                Fail::Refused(code, msg) => {
                    for n in &notices {
                        write_status(writer, n)?;
                    }
                    return write_frame(writer, TAG_STATUS, &raw_status(code, &msg)).map_err(wr);
                }
                Fail::Transport(detail) => {
                    attempts += 1;
                    if attempts > 2 {
                        return fail_reply(
                            writer,
                            ctx,
                            SessionFail::Lost(format!("session {id}: close failed: {detail}")),
                        );
                    }
                    match routed.recover(ctx, &detail) {
                        Ok(n) => notices.extend(n),
                        Err(fail) => return fail_reply(writer, ctx, fail),
                    }
                }
            },
        }
    };
    for n in &notices {
        write_status(writer, n)?;
    }
    let mut out = std::mem::take(&mut routed.pending);
    out.extend_from_slice(&closed.scores);
    write_scores(writer, id, &out)?;
    let mut body = Vec::with_capacity(8 + 8 + 8 + 1 + 4);
    body.extend_from_slice(&id.to_le_bytes());
    body.extend_from_slice(&closed.samples.to_le_bytes());
    body.extend_from_slice(&closed.flits.to_le_bytes());
    body.push(closed.padded_tail as u8);
    body.extend_from_slice(&(closed.tail_valid as u32).to_le_bytes());
    write_frame(writer, TAG_CLOSED, &body).map_err(wr)
}

fn handle_suspend(
    st: &mut RouteState,
    ctx: &Ctx,
    writer: &mut impl Write,
    payload: &[u8],
) -> std::result::Result<(), NetError> {
    let mut b = payload;
    let id = take_u64(&mut b, "suspend session id")?;
    if !b.is_empty() {
        return Err(NetError::BadFrame(format!("{} trailing bytes after suspend", b.len())));
    }
    let mut routed = claim(st, id)?;
    let mut notices = match routed.maybe_migrate(ctx) {
        Ok(n) => n,
        Err(fail) => return fail_reply(writer, ctx, fail),
    };
    let mut attempts = 0u32;
    let (ticket, scores) = loop {
        match routed.live().suspend() {
            Ok(ts) => break ts,
            Err(e) => match classify(e) {
                Fail::Refused(code, msg) => {
                    for n in &notices {
                        write_status(writer, n)?;
                    }
                    return write_frame(writer, TAG_STATUS, &raw_status(code, &msg)).map_err(wr);
                }
                Fail::Transport(detail) => {
                    attempts += 1;
                    if attempts > 2 {
                        return fail_reply(
                            writer,
                            ctx,
                            SessionFail::Lost(format!("session {id}: suspend failed: {detail}")),
                        );
                    }
                    match routed.recover(ctx, &detail) {
                        Ok(n) => notices.extend(n),
                        Err(fail) => return fail_reply(writer, ctx, fail),
                    }
                }
            },
        }
    };
    for n in &notices {
        write_status(writer, n)?;
    }
    let mut out = std::mem::take(&mut routed.pending);
    out.extend_from_slice(&scores);
    write_scores(writer, id, &out)?;
    let mut body = Vec::with_capacity(8 + ticket.len());
    body.extend_from_slice(&id.to_le_bytes());
    body.extend_from_slice(&ticket);
    write_frame(writer, TAG_SUSPENDED, &body).map_err(wr)
}

// ---------------------------------------------------------------------------
// Health prober
// ---------------------------------------------------------------------------

fn probe_loop(ctx: &Ctx, stop: &AtomicBool) {
    let period = Duration::from_millis(ctx.cfg.heartbeat_ms.max(1));
    while !stop.load(Ordering::SeqCst) {
        // Probe every slot, Down ones included — a successful ping is how
        // a restarted worker rejoins the ring.
        for (i, info) in ctx.pool.infos().iter().enumerate() {
            if stop.load(Ordering::SeqCst) {
                return;
            }
            let ok = connect_worker(ctx, &info.addr).and_then(|mut up| up.ping()).is_ok();
            if ok {
                ctx.stats.pings_ok.fetch_add(1, Ordering::SeqCst);
                ctx.pool.record_success(i);
            } else {
                ctx.stats.pings_failed.fetch_add(1, Ordering::SeqCst);
                if ctx.pool.record_failure(i) {
                    ctx.stats.ejections.fetch_add(1, Ordering::SeqCst);
                }
            }
        }
        std::thread::sleep(period);
    }
}

// ---------------------------------------------------------------------------
// Router
// ---------------------------------------------------------------------------

/// Decrements the live-connection gauge when a handler ends, by any path.
struct ConnGuard(Arc<AtomicUsize>);

impl Drop for ConnGuard {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::SeqCst);
    }
}

/// The session router process: accept loop, per-connection handler
/// threads, health prober. See the module docs for the recovery model.
pub struct Router {
    addr: SocketAddr,
    ctx: Arc<Ctx>,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    prober: Option<JoinHandle<()>>,
}

impl Router {
    /// Bind `cfg.addr` (port 0 picks a free port) and start routing to
    /// `cfg.workers`.
    pub fn start(cfg: &RouterCfg) -> Result<Router> {
        anyhow::ensure!(
            !cfg.workers.is_empty(),
            "[fabric.router] workers must name at least one fsead net address"
        );
        let pool = Arc::new(WorkerPool::new(cfg.max_failures));
        for w in &cfg.workers {
            pool.add(w);
        }
        let stats = Arc::new(RouterStats::default());
        let ctx = Arc::new(Ctx { pool, stats, cfg: cfg.clone() });
        let listener = TcpListener::bind(cfg.addr.as_str())
            .with_context(|| format!("binding the router listener on {}", cfg.addr))?;
        let local = listener.local_addr().context("resolving the router listener address")?;
        let stop = Arc::new(AtomicBool::new(false));
        let limit = cfg.max_connections.max(1);
        let live = Arc::new(AtomicUsize::new(0));
        let ctx2 = Arc::clone(&ctx);
        let stop2 = Arc::clone(&stop);
        let accept = std::thread::Builder::new()
            .name("router".into())
            .spawn(move || loop {
                match listener.accept() {
                    Ok((mut stream, _)) => {
                        if stop2.load(Ordering::SeqCst) {
                            break;
                        }
                        if live.load(Ordering::SeqCst) >= limit {
                            let _ = write_frame(
                                &mut stream,
                                TAG_STATUS,
                                &encode_status(&NetError::ServerBusy),
                            );
                            continue;
                        }
                        live.fetch_add(1, Ordering::SeqCst);
                        let guard = ConnGuard(Arc::clone(&live));
                        let ctx = Arc::clone(&ctx2);
                        let _ = std::thread::Builder::new().name("route-conn".into()).spawn(
                            move || {
                                let _guard = guard;
                                let _ = route_connection(stream, &ctx);
                            },
                        );
                    }
                    Err(e) => {
                        if stop2.load(Ordering::SeqCst) {
                            break;
                        }
                        std::thread::sleep(accept_retry_delay(&e));
                    }
                }
            })
            .expect("spawn router accept thread");
        let prober = if cfg.heartbeat_ms > 0 {
            let ctx3 = Arc::clone(&ctx);
            let stop3 = Arc::clone(&stop);
            Some(
                std::thread::Builder::new()
                    .name("router-probe".into())
                    .spawn(move || probe_loop(&ctx3, &stop3))
                    .expect("spawn router probe thread"),
            )
        } else {
            None
        };
        Ok(Router { addr: local, ctx, stop, accept: Some(accept), prober })
    }

    /// The bound client-facing address (useful with port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The worker membership — tests drive joins/drains through this too.
    pub fn pool(&self) -> Arc<WorkerPool> {
        Arc::clone(&self.ctx.pool)
    }

    /// Join a worker (or revive a drained/ejected one): its hash ranges
    /// re-shard onto it as live sessions hit their next forward.
    pub fn add_worker(&self, addr: &str) {
        self.ctx.pool.add(addr);
    }

    /// Gracefully drain a worker: no new placements, and every session it
    /// holds migrates away (suspend → carry ticket → resume) at its next
    /// frame. Returns false for an unknown address.
    pub fn drain_worker(&self, addr: &str) -> bool {
        self.ctx.pool.drain(addr)
    }

    pub fn stats(&self) -> RouterSnapshot {
        self.ctx.stats.snapshot()
    }

    /// Stop accepting and join the router threads. Live connections keep
    /// their sessions until their clients hang up.
    pub fn stop(mut self) {
        self.stop_impl();
    }

    fn stop_impl(&mut self) {
        if self.accept.is_none() && self.prober.is_none() {
            return;
        }
        self.stop.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        if let Some(h) = self.prober.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Router {
    fn drop(&mut self) {
        self.stop_impl();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::net::decode_status;

    #[test]
    fn raw_status_matches_the_wire_codec() {
        let payload = raw_status(21, "worker lost: boom");
        let (code, msg) = decode_status(&payload).unwrap();
        assert_eq!(code, 21);
        assert_eq!(msg, "worker lost: boom");
        // Byte-compatible with what encode_status produces for the same
        // code/message — forwarding a worker refusal is transparent.
        let owned = encode_status(&NetError::WorkerLost("boom".into()));
        let (c2, m2) = decode_status(&owned).unwrap();
        assert_eq!(raw_status(c2, &m2), owned);
    }

    #[test]
    fn classify_separates_refusals_from_transport_failures() {
        let refused = anyhow::Error::new(NetStatus { code: 16, message: "busy".into() });
        match classify(refused) {
            Fail::Refused(code, msg) => {
                assert_eq!(code, 16);
                assert_eq!(msg, "busy");
            }
            Fail::Transport(_) => panic!("a NetStatus must classify as a refusal"),
        }
        let io = anyhow::Error::new(std::io::Error::new(std::io::ErrorKind::BrokenPipe, "gone"));
        assert!(matches!(classify(io), Fail::Transport(_)));
    }

    #[test]
    fn stats_snapshot_starts_zeroed() {
        assert_eq!(RouterStats::default().snapshot(), RouterSnapshot::default());
    }

    #[test]
    fn router_refuses_an_empty_worker_list() {
        let cfg = RouterCfg { addr: "127.0.0.1:0".into(), ..Default::default() };
        assert!(Router::start(&cfg).is_err());
    }
}
