//! Worker membership for the session router: health tracking and a
//! consistent-hash ring over the downstream `fsead net` processes.
//!
//! The ring is the classic virtual-node construction: every *routable*
//! worker (not ejected, not draining) contributes [`VNODES`] points on a
//! `u64` circle, hashed from its address alone, and a session id's owner
//! is the first point clockwise from the id's hash. Because the points
//! depend only on the worker addresses, ownership is deterministic across
//! router restarts, and a membership change moves only the hash ranges
//! adjacent to the joining/leaving worker's points — the property the
//! drain/re-shard tests pin down.
//!
//! Health is consecutive-failure counting: probe or forward failures move
//! a worker `Healthy → Suspect(n) → Down` (ejected from the ring at
//! `max_failures`); any success snaps it back to `Healthy`, which lets a
//! restarted worker rejoin automatically once the prober reaches it.
//! Every membership or ring-visibility change bumps an epoch counter;
//! router connection handlers re-check their session's owner when the
//! epoch moves and migrate lazily.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Virtual nodes per worker — enough to keep ranges balanced for the
/// small fleets a router fronts (N ≤ a few dozen).
pub const VNODES: usize = 32;

/// splitmix64 — the ring's mixing function. Dependency-free, stable, and
/// good enough avalanche for placement (this is load balancing, not
/// cryptography).
pub fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

/// FNV-1a over a byte string — seeds the per-worker ring points.
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xCBF2_9CE4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// One worker's health as the router sees it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WorkerHealth {
    Healthy,
    /// `n` consecutive failures — still routable until ejection.
    Suspect(u32),
    /// Ejected from the ring; revived by the next successful probe.
    Down,
}

/// A snapshot row for stats/tests: one worker's address, health and
/// session gauge.
#[derive(Clone, Debug)]
pub struct WorkerInfo {
    pub addr: String,
    pub health: WorkerHealth,
    pub draining: bool,
    pub sessions: u64,
}

struct Slot {
    addr: String,
    health: WorkerHealth,
    draining: bool,
    sessions: u64,
}

impl Slot {
    fn routable(&self) -> bool {
        self.health != WorkerHealth::Down && !self.draining
    }
}

/// Membership + health + ring for a router's worker fleet. All methods
/// take `&self`; the pool is shared across connection handlers and the
/// health prober as an `Arc`.
pub struct WorkerPool {
    slots: Mutex<Vec<Slot>>,
    /// Cached ring, rebuilt when `epoch` moves: sorted `(point, slot)`.
    ring: Mutex<(u64, Vec<(u64, usize)>)>,
    /// Bumped on every membership / ring-visibility change.
    epoch: AtomicU64,
    max_failures: u32,
}

impl WorkerPool {
    pub fn new(max_failures: u32) -> WorkerPool {
        WorkerPool {
            slots: Mutex::new(Vec::new()),
            // Epoch starts at 1 so a cached `0` is always stale.
            ring: Mutex::new((0, Vec::new())),
            epoch: AtomicU64::new(1),
            max_failures: max_failures.max(1),
        }
    }

    /// Add a worker (or revive/undrain one previously added with the same
    /// address). Returns its slot index, stable for the pool's lifetime.
    pub fn add(&self, addr: &str) -> usize {
        let mut slots = self.slots.lock().unwrap();
        let idx = match slots.iter().position(|s| s.addr == addr) {
            Some(i) => {
                slots[i].health = WorkerHealth::Healthy;
                slots[i].draining = false;
                i
            }
            None => {
                slots.push(Slot {
                    addr: addr.to_string(),
                    health: WorkerHealth::Healthy,
                    draining: false,
                    sessions: 0,
                });
                slots.len() - 1
            }
        };
        drop(slots);
        self.bump();
        idx
    }

    /// Graceful leave: stop placing sessions on `addr`; handlers migrate
    /// its sessions away at their next frame. Returns false for an
    /// unknown address.
    pub fn drain(&self, addr: &str) -> bool {
        let mut slots = self.slots.lock().unwrap();
        let Some(i) = slots.iter().position(|s| s.addr == addr) else {
            return false;
        };
        slots[i].draining = true;
        drop(slots);
        self.bump();
        true
    }

    /// A probe/forward against `idx` succeeded: snap back to `Healthy`
    /// (reviving an ejected worker — e.g. one restarted after a crash).
    pub fn record_success(&self, idx: usize) {
        let mut slots = self.slots.lock().unwrap();
        let Some(s) = slots.get_mut(idx) else { return };
        let was = s.health;
        s.health = WorkerHealth::Healthy;
        let visibility_changed = was == WorkerHealth::Down;
        drop(slots);
        if visibility_changed {
            self.bump();
        }
    }

    /// A probe/forward against `idx` failed. Returns true when this
    /// failure crossed `max_failures` and ejected the worker.
    pub fn record_failure(&self, idx: usize) -> bool {
        let mut slots = self.slots.lock().unwrap();
        let Some(s) = slots.get_mut(idx) else { return false };
        let n = match s.health {
            WorkerHealth::Healthy => 1,
            WorkerHealth::Suspect(n) => n + 1,
            WorkerHealth::Down => return false,
        };
        let ejected = n >= self.max_failures;
        s.health = if ejected { WorkerHealth::Down } else { WorkerHealth::Suspect(n) };
        drop(slots);
        if ejected {
            self.bump();
        }
        ejected
    }

    /// Immediate ejection (e.g. a connection died mid-frame — no point
    /// counting to `max_failures` against a peer that is gone).
    pub fn eject(&self, idx: usize) {
        let mut slots = self.slots.lock().unwrap();
        let Some(s) = slots.get_mut(idx) else { return };
        if s.health == WorkerHealth::Down {
            return;
        }
        s.health = WorkerHealth::Down;
        drop(slots);
        self.bump();
    }

    /// The current membership epoch; handlers cache it and re-check their
    /// session's owner when it moves.
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::SeqCst)
    }

    fn bump(&self) {
        self.epoch.fetch_add(1, Ordering::SeqCst);
    }

    /// The ring owner for `key` (hash a session id first — see
    /// [`splitmix64`]), or `None` when no worker is routable.
    pub fn owner(&self, key: u64) -> Option<usize> {
        self.candidates(key).first().copied()
    }

    /// Every routable worker in ring order starting at `key`'s successor,
    /// deduplicated — the fail-over preference list: try `[0]`, then `[1]`…
    pub fn candidates(&self, key: u64) -> Vec<usize> {
        let slots = self.slots.lock().unwrap();
        let epoch = self.epoch();
        let mut ring = self.ring.lock().unwrap();
        if ring.0 != epoch {
            let mut points: Vec<(u64, usize)> = Vec::new();
            for (i, s) in slots.iter().enumerate() {
                if !s.routable() {
                    continue;
                }
                let base = fnv1a(s.addr.as_bytes());
                for v in 0..VNODES {
                    points.push((splitmix64(base ^ (v as u64).wrapping_mul(0x9E37)), i));
                }
            }
            points.sort_unstable();
            *ring = (epoch, points);
        }
        let points = &ring.1;
        if points.is_empty() {
            return Vec::new();
        }
        let start = points.partition_point(|&(p, _)| p <= key);
        let mut seen = Vec::new();
        for off in 0..points.len() {
            let (_, slot) = points[(start + off) % points.len()];
            if !seen.contains(&slot) {
                seen.push(slot);
            }
        }
        seen
    }

    /// The address of slot `idx` (panics on a bad index — indices come
    /// from this pool and are never removed).
    pub fn addr_of(&self, idx: usize) -> String {
        self.slots.lock().unwrap()[idx].addr.clone()
    }

    /// Is `idx` currently in the ring (healthy-or-suspect, not draining)?
    pub fn is_routable(&self, idx: usize) -> bool {
        self.slots.lock().unwrap().get(idx).map(|s| s.routable()).unwrap_or(false)
    }

    /// Routable worker count — 0 means new sessions must be shed.
    pub fn routable_count(&self) -> usize {
        self.slots.lock().unwrap().iter().filter(|s| s.routable()).count()
    }

    /// Adjust the live-session gauge for `idx` by `delta`.
    pub fn session_delta(&self, idx: usize, delta: i64) {
        let mut slots = self.slots.lock().unwrap();
        if let Some(s) = slots.get_mut(idx) {
            s.sessions = s.sessions.saturating_add_signed(delta);
        }
    }

    /// Snapshot every worker for stats/tests.
    pub fn infos(&self) -> Vec<WorkerInfo> {
        self.slots
            .lock()
            .unwrap()
            .iter()
            .map(|s| WorkerInfo {
                addr: s.addr.clone(),
                health: s.health,
                draining: s.draining,
                sessions: s.sessions,
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool(addrs: &[&str]) -> WorkerPool {
        let p = WorkerPool::new(3);
        for a in addrs {
            p.add(a);
        }
        p
    }

    #[test]
    fn ownership_is_deterministic_and_add_order_independent() {
        let a = pool(&["h1:1", "h2:2", "h3:3"]);
        let b = pool(&["h3:3", "h1:1", "h2:2"]);
        for key in 0..512u64 {
            let k = splitmix64(key);
            let oa = a.addr_of(a.owner(k).unwrap());
            let ob = b.addr_of(b.owner(k).unwrap());
            assert_eq!(oa, ob, "key {key}: ring must not depend on add order");
        }
    }

    #[test]
    fn join_moves_only_the_new_workers_range() {
        let p = pool(&["h1:1", "h2:2"]);
        let keys: Vec<u64> = (0..2048u64).map(splitmix64).collect();
        let before: Vec<String> =
            keys.iter().map(|&k| p.addr_of(p.owner(k).unwrap())).collect();
        p.add("h3:3");
        let mut moved = 0usize;
        for (i, &k) in keys.iter().enumerate() {
            let now = p.addr_of(p.owner(k).unwrap());
            if now != before[i] {
                // The consistent-hash contract: a key only ever moves TO
                // the joining worker, never between the incumbents.
                assert_eq!(now, "h3:3", "key {i} moved between incumbents");
                moved += 1;
            }
        }
        // ~1/3 of the space should move; allow a generous band.
        assert!(
            moved > keys.len() / 8 && moved < keys.len() * 3 / 4,
            "implausible moved fraction: {moved}/{}",
            keys.len()
        );
    }

    #[test]
    fn consecutive_failures_eject_and_success_revives() {
        let p = pool(&["h1:1", "h2:2"]);
        let e0 = p.epoch();
        assert!(!p.record_failure(0));
        assert!(!p.record_failure(0));
        assert_eq!(p.infos()[0].health, WorkerHealth::Suspect(2));
        assert!(p.is_routable(0), "suspect workers stay in the ring");
        assert!(p.record_failure(0), "third failure ejects at max_failures = 3");
        assert_eq!(p.infos()[0].health, WorkerHealth::Down);
        assert!(!p.is_routable(0));
        assert!(p.epoch() > e0, "ejection must bump the epoch");
        // Every candidate list now avoids the ejected worker.
        for key in 0..64u64 {
            assert!(!p.candidates(splitmix64(key)).contains(&0));
        }
        let e1 = p.epoch();
        p.record_success(0);
        assert_eq!(p.infos()[0].health, WorkerHealth::Healthy);
        assert!(p.epoch() > e1, "revival must bump the epoch");
        assert!(p.is_routable(0));
    }

    #[test]
    fn drain_removes_from_ring_but_keeps_the_slot() {
        let p = pool(&["h1:1", "h2:2"]);
        assert!(p.drain("h1:1"));
        assert!(!p.drain("nope:0"));
        assert!(!p.is_routable(0));
        assert_eq!(p.routable_count(), 1);
        for key in 0..64u64 {
            assert_eq!(p.owner(splitmix64(key)), Some(1));
        }
        // Re-adding the same address undrains it.
        assert_eq!(p.add("h1:1"), 0);
        assert!(p.is_routable(0));
    }

    #[test]
    fn no_routable_workers_means_no_owner() {
        let p = pool(&["h1:1"]);
        p.eject(0);
        assert_eq!(p.owner(42), None);
        assert!(p.candidates(42).is_empty());
        assert_eq!(p.routable_count(), 0);
    }

    #[test]
    fn session_gauge_tracks_deltas() {
        let p = pool(&["h1:1"]);
        p.session_delta(0, 2);
        p.session_delta(0, -1);
        assert_eq!(p.infos()[0].sessions, 1);
        p.session_delta(0, -5);
        assert_eq!(p.infos()[0].sessions, 0, "gauge saturates at zero");
    }
}
