//! Blocking client for the `fsead net` frame protocol — the wire-side
//! twin of [`super::server::Session`], used by the integration tests and
//! `benches/net_sessions.rs`.
//!
//! One [`NetClient`] is one TCP connection is (at most) one live session;
//! every call writes one frame and blocks for its deterministic reply
//! (see [`super::net`] for the protocol). Server refusals arrive as
//! `Status` frames and surface as [`NetStatus`] errors — downcast with
//! `err.downcast_ref::<NetStatus>()` to read the wire code, e.g. to tell
//! an admission `saturated` (retry later) from a `bad_frame`.

use std::io::BufReader;
use std::net::{TcpStream, ToSocketAddrs};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::message::{decode_f32_le, encode_f32_le};
use super::net::{
    decode_status, is_notice, read_frame, write_frame, NetError, TAG_CLOSE, TAG_CLOSED, TAG_OPEN,
    TAG_OPENED, TAG_PING, TAG_PONG, TAG_PUSH, TAG_RESUME, TAG_RESUMED, TAG_SCORES, TAG_SUSPEND,
    TAG_SUSPENDED,
};

/// A typed `Status` reply from the server. The `code` values are the
/// `STATUS_*` constants in [`super::net`] — admission refusals are 1–4.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct NetStatus {
    pub code: u16,
    pub message: String,
}

impl std::fmt::Display for NetStatus {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "server status {}: {}", self.code, self.message)
    }
}

impl std::error::Error for NetStatus {}

/// What `Close` returns: the drained tail scores plus the same accounting
/// as an in-process [`super::server::SessionClose`].
#[derive(Clone, Debug)]
pub struct NetClose {
    pub scores: Vec<f32>,
    pub samples: u64,
    pub flits: u64,
    pub padded_tail: bool,
    pub tail_valid: usize,
}

/// Blocking connection to a [`super::net::NetServer`] (or the session
/// router, which speaks the same protocol).
pub struct NetClient {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    session: Option<u64>,
    /// The pblock named by the last `Opened`/`Resumed` ack — the router
    /// forwards it to its own client verbatim.
    pblock: u32,
    /// Informational router notices (`rerouted` / `resume_gap` status
    /// frames) that preceded replies — collect with
    /// [`NetClient::take_notices`].
    notices: Vec<NetStatus>,
}

impl NetClient {
    /// Connect to `addr` (e.g. `127.0.0.1:9191`).
    pub fn connect(addr: &str) -> Result<NetClient> {
        let stream = TcpStream::connect(addr)
            .with_context(|| format!("connecting to fsead net server at {addr}"))?;
        Self::from_stream(stream)
    }

    /// [`NetClient::connect`] with a bound on the connect itself — a
    /// black-holed address fails in `timeout` instead of the OS default
    /// (minutes). Resolves `addr` and tries each candidate address once.
    pub fn connect_timeout(addr: &str, timeout: Duration) -> Result<NetClient> {
        let addrs: Vec<_> = addr
            .to_socket_addrs()
            .with_context(|| format!("resolving fsead net server address {addr}"))?
            .collect();
        let mut last: Option<std::io::Error> = None;
        for sa in &addrs {
            match TcpStream::connect_timeout(sa, timeout) {
                Ok(stream) => return Self::from_stream(stream),
                Err(e) => last = Some(e),
            }
        }
        match last {
            Some(e) => {
                Err(e).with_context(|| format!("connecting to fsead net server at {addr}"))
            }
            None => bail!("fsead net server address {addr} resolved to nothing"),
        }
    }

    /// Reconnect with exponential back-off until `deadline` elapses: the
    /// delay starts at `base` and doubles per attempt. Returns the first
    /// successful connection, or the last error once the budget is spent.
    /// Used by the router to ride out worker restarts; callers re-`resume`
    /// their session ticket on the fresh connection themselves.
    pub fn reconnect_with_backoff(
        addr: &str,
        io_timeout: Option<Duration>,
        base: Duration,
        deadline: Duration,
    ) -> Result<NetClient> {
        let t0 = Instant::now();
        let mut delay = base.max(Duration::from_millis(1));
        let mut attempts = 0u32;
        loop {
            attempts += 1;
            let left = deadline.saturating_sub(t0.elapsed());
            // Never pass a zero connect timeout (it means "no limit").
            let connect_budget = left.max(Duration::from_millis(1));
            match Self::connect_timeout(addr, connect_budget) {
                Ok(mut c) => {
                    c.set_io_timeout(io_timeout)?;
                    return Ok(c);
                }
                Err(e) => {
                    if t0.elapsed() + delay >= deadline {
                        return Err(e.context(format!(
                            "reconnecting to {addr}: gave up after {attempts} attempt(s) \
                             in {:?}",
                            t0.elapsed()
                        )));
                    }
                    std::thread::sleep(delay);
                    delay = (delay * 2).min(Duration::from_secs(1));
                }
            }
        }
    }

    fn from_stream(stream: TcpStream) -> Result<NetClient> {
        let reader = BufReader::new(stream.try_clone().context("cloning the net socket")?);
        Ok(NetClient { reader, writer: stream, session: None, pblock: 0, notices: Vec::new() })
    }

    /// Bound every socket read and write: a wedged server surfaces as a
    /// timeout error on the pending call instead of hanging this client
    /// forever. `None` removes the bound.
    pub fn set_io_timeout(&mut self, timeout: Option<Duration>) -> Result<()> {
        let stream = self.reader.get_ref();
        stream.set_read_timeout(timeout).context("setting the read timeout")?;
        stream.set_write_timeout(timeout).context("setting the write timeout")?;
        self.writer.set_read_timeout(timeout).context("setting the read timeout")?;
        self.writer.set_write_timeout(timeout).context("setting the write timeout")?;
        Ok(())
    }

    /// Liveness probe: one `Ping` frame, block for the `Pong`. Needs no
    /// session — the router's health loop is built on this.
    pub fn ping(&mut self) -> Result<()> {
        self.send(TAG_PING, &[])?;
        self.reply(TAG_PONG, "ping")?;
        Ok(())
    }

    /// Drain the informational router notices (`rerouted` / `resume_gap`)
    /// observed since the last call. Empty when talking to a worker
    /// directly — only the router emits notices.
    pub fn take_notices(&mut self) -> Vec<NetStatus> {
        std::mem::take(&mut self.notices)
    }

    /// The live session id, once `open` or `resume` succeeded.
    pub fn session(&self) -> Option<u64> {
        self.session
    }

    /// The pblock from the last `Opened`/`Resumed` ack (0 before either).
    pub fn pblock(&self) -> u32 {
        self.pblock
    }

    /// Read one reply frame; a `Status` frame becomes a typed error —
    /// except informational router notices (`rerouted` / `resume_gap`),
    /// which are recorded into [`NetClient::take_notices`] and skipped:
    /// the real reply follows them on the wire.
    fn reply(&mut self, expect: u8, what: &str) -> Result<Vec<u8>> {
        loop {
            let (tag, payload) = match read_frame(&mut self.reader) {
                Ok(Some(f)) => f,
                Ok(None) => bail!("server hung up waiting for {what}"),
                Err(e) => return Err(anyhow::Error::new(e).context(format!("reading {what}"))),
            };
            if tag == super::net::TAG_STATUS {
                let (code, message) = decode_status(&payload)
                    .map_err(|e| anyhow::Error::new(e).context("malformed status frame"))?;
                if is_notice(code) {
                    self.notices.push(NetStatus { code, message });
                    continue;
                }
                return Err(anyhow::Error::new(NetStatus { code, message })
                    .context(format!("server refused {what}")));
            }
            if tag != expect {
                bail!("expected frame 0x{expect:02x} for {what}, got 0x{tag:02x}");
            }
            return Ok(payload);
        }
    }

    fn send(&mut self, tag: u8, payload: &[u8]) -> Result<()> {
        write_frame(&mut self.writer, tag, payload).context("writing frame")
    }

    /// Open a session: dimensionality `d`, optional pinned pblock, warm-up
    /// prefix (a whole number of rows). Returns the session id.
    pub fn open(&mut self, d: usize, pblock: Option<usize>, warmup: &[f32]) -> Result<u64> {
        if self.session.is_some() {
            bail!("a session is already open on this client");
        }
        let mut payload = Vec::with_capacity(12 + warmup.len() * 4);
        payload.extend_from_slice(&(d as u32).to_le_bytes());
        payload.extend_from_slice(&(pblock.unwrap_or(0) as u32).to_le_bytes());
        payload.extend_from_slice(&(warmup.len() as u32).to_le_bytes());
        encode_f32_le(warmup, &mut payload);
        self.send(TAG_OPEN, &payload)?;
        let reply = self.reply(TAG_OPENED, "open")?;
        let (id, pblock) = parse_id_u32(&reply, "opened")?;
        self.session = Some(id);
        self.pblock = pblock;
        Ok(id)
    }

    /// Resume a session from ticket bytes (as returned by [`NetClient::suspend`]
    /// — possibly by a different client against a different server process).
    /// Returns the session id.
    pub fn resume(&mut self, ticket: &[u8]) -> Result<u64> {
        if self.session.is_some() {
            bail!("a session is already open on this client");
        }
        self.send(TAG_RESUME, ticket)?;
        let reply = self.reply(TAG_RESUMED, "resume")?;
        let (id, pblock) = parse_id_u32(&reply, "resumed")?;
        self.session = Some(id);
        self.pblock = pblock;
        Ok(id)
    }

    /// Push a block of samples (row-major, a whole number of rows) and
    /// block for its `Scores` reply — every score the block is owed in
    /// lock-step mode, whatever had arrived otherwise.
    pub fn push(&mut self, samples: &[f32]) -> Result<Vec<f32>> {
        let id = self.session.context("no session open on this client")?;
        let mut payload = Vec::with_capacity(8 + samples.len() * 4);
        payload.extend_from_slice(&id.to_le_bytes());
        encode_f32_le(samples, &mut payload);
        self.send(TAG_PUSH, &payload)?;
        let reply = self.reply(TAG_SCORES, "push")?;
        parse_scores(&reply, id)
    }

    /// Close the session: TLAST flush, tail scores, accounting.
    pub fn close(&mut self) -> Result<NetClose> {
        let id = self.session.take().context("no session open on this client")?;
        self.send(TAG_CLOSE, &id.to_le_bytes())?;
        let scores = parse_scores(&self.reply(TAG_SCORES, "close")?, id)?;
        let reply = self.reply(TAG_CLOSED, "close")?;
        let mut b = reply.as_slice();
        let rid = take_u64(&mut b, "closed session id")?;
        if rid != id {
            bail!("closed frame names session {rid}, expected {id}");
        }
        let samples = take_u64(&mut b, "closed samples")?;
        let flits = take_u64(&mut b, "closed flits")?;
        let padded_tail = take_u8(&mut b, "closed padded_tail")? != 0;
        let tail_valid = take_u32(&mut b, "closed tail_valid")? as usize;
        Ok(NetClose { scores, samples, flits, padded_tail, tail_valid })
    }

    /// Suspend the session into a portable ticket. Returns the raw ticket
    /// bytes (feed them to [`NetClient::resume`] on any server built from
    /// the same config) plus any scores that were still in flight.
    pub fn suspend(&mut self) -> Result<(Vec<u8>, Vec<f32>)> {
        let id = self.session.take().context("no session open on this client")?;
        self.send(TAG_SUSPEND, &id.to_le_bytes())?;
        let scores = parse_scores(&self.reply(TAG_SCORES, "suspend")?, id)?;
        let reply = self.reply(TAG_SUSPENDED, "suspend")?;
        let mut b = reply.as_slice();
        let rid = take_u64(&mut b, "suspended session id")?;
        if rid != id {
            bail!("suspended frame names session {rid}, expected {id}");
        }
        Ok((b.to_vec(), scores))
    }
}

fn parse_id_u32(payload: &[u8], what: &str) -> Result<(u64, u32)> {
    let mut b = payload;
    let id = take_u64(&mut b, what)?;
    let v = take_u32(&mut b, what)?;
    Ok((id, v))
}

fn parse_scores(payload: &[u8], id: u64) -> Result<Vec<f32>> {
    let mut b = payload;
    let rid = take_u64(&mut b, "scores session id")?;
    if rid != id {
        bail!("scores frame names session {rid}, expected {id}");
    }
    if b.len() % 4 != 0 {
        bail!("scores body of {} bytes is not a whole number of f32 values", b.len());
    }
    let mut scores = Vec::new();
    decode_f32_le(b, &mut scores);
    Ok(scores)
}

fn take<'a>(b: &mut &'a [u8], n: usize, what: &str) -> Result<&'a [u8]> {
    if b.len() < n {
        bail!("truncated {what}");
    }
    let (head, rest) = b.split_at(n);
    *b = rest;
    Ok(head)
}

fn take_u8(b: &mut &[u8], what: &str) -> Result<u8> {
    Ok(take(b, 1, what)?[0])
}

fn take_u32(b: &mut &[u8], what: &str) -> Result<u32> {
    Ok(u32::from_le_bytes(take(b, 4, what)?.try_into().unwrap()))
}

fn take_u64(b: &mut &[u8], what: &str) -> Result<u64> {
    Ok(u64::from_le_bytes(take(b, 8, what)?.try_into().unwrap()))
}
