//! Partition supervisor: the fault-recovery control plane.
//!
//! # Fault model
//!
//! The fabric assumes **fail-corrupt, fail-stop partitions on a reliable
//! shell**: a reconfigurable partition can corrupt its detector state
//! (SEU in region memory → non-finite scores), lose a lane worker
//! (panicked or exited thread, the software analogue of hung region
//! logic), or wedge mid-flit — but the static shell (DMA framing, control
//! surfaces, decouplers, this supervisor) stays correct. Faults are
//! detected at three surfaces:
//!
//! - **Output screen** (in the service loop): every score flit of an
//!   armed partition is checked for non-finite values before it reaches
//!   downstream consumers or the score stats.
//! - **Worker containment** (in the lane pool): a panicking detector job
//!   is caught, its lane state rolled back, and the job retried once; a
//!   dead worker surfaces as a clean `Err` from scoring.
//! - **Heartbeat watchdog** (this thread): each service loop ticks a
//!   per-partition beat and raises a `processing` flag strictly while the
//!   RM is scoring. A partition whose beat is frozen *while processing*
//!   past `stall_timeout_ms` is flagged; a partition blocked on an empty
//!   inbox is healthy no matter how long it waits — upstream starvation
//!   is not a partition fault.
//!
//! # Escalation ladder
//!
//! Recovery escalates through three rungs, each strictly more expensive
//! and more disruptive than the last:
//!
//! 1. **Rung 0 — in-place containment** (no dark window): lane-panic
//!    rollback + retry inside the worker, dead-worker respawn + flit
//!    retry in the service loop. Bit-exact when the retry succeeds.
//! 2. **Rung 1 — RM reload**: the service loop files a [`ReloadRequest`]
//!    (and blocks, bounded, so the swap lands at the very next flit); the
//!    supervisor waits out an exponential backoff, stages a fresh RM
//!    through the existing DFX stage/quiesce/replace path — charging the
//!    Table-13 dark window exactly like a planned swap — and, when a
//!    checkpoint exists, restores the last snapshot into the staged RM
//!    (`preserve_state` skips the post-swap reset) so the partition
//!    *resumes* instead of cold-starting.
//! 3. **Rung 2 — quarantine**: after `max_reloads` rung-1 attempts the
//!    partition is permanently isolated — the decoupler latches
//!    ([`Decoupler::quarantine`]): DECOUPLE asserted, then disabled so no
//!    staged swap can re-enable the region. Downstream combos detect the
//!    closed input, consult the quarantine flag and renormalize over the
//!    surviving partitions.
//!
//! Every detection and every rung transition is recorded as a typed
//! [`FaultEvent`] on the partition's fault port, drained into
//! `RunOutput::fault_events` (and surfaced per-session by the fabric
//! server), so a fault campaign is fully auditable after the run. The
//! port also keeps cumulative, non-draining counters (events recorded,
//! rung-1 reloads, rung-2 quarantines) that the operator plane's
//! [`crate::fabric::operator::FabricSnapshot`] reads live — session
//! bookkeeping and the `/metrics` scrape never race over the same list.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::decoupler::Decoupler;
use super::faults::{FaultEvent, ReloadRequest};
use super::hotswap::PblockCtl;
use super::reconfig::DfxManager;
use super::snapshot::restore_rm;
use crate::config::{DarkPolicy, DetectorHyper, FaultsCfg, RmKind};
use crate::detectors::DetectorKind;

/// One partition watched by the supervisor — everything needed to stage a
/// replacement RM identical (modulo restored state) to the configured one.
pub struct SupervisorTarget {
    pub pblock: usize,
    pub ctl: Arc<PblockCtl>,
    pub decoupler: Arc<Decoupler>,
    pub kind: DetectorKind,
    pub r: usize,
    pub d: usize,
    pub seed: u64,
    pub warmup: Vec<f32>,
    pub lanes: usize,
    pub quantize: bool,
}

/// Everything the supervisor thread owns.
pub struct SupervisorEnv {
    pub dfx: DfxManager,
    pub faults: FaultsCfg,
    pub hyper: DetectorHyper,
    pub chunk: usize,
    pub samples_per_sec: f64,
    pub policy: DarkPolicy,
}

/// Per-target watchdog + ladder state.
struct TargetState {
    reloads: u32,
    last_beat: u64,
    last_change: Instant,
    stall_latched: bool,
    quarantined: bool,
}

/// Spawn the partition supervisor. It polls each target's health surface
/// (~200 µs period), runs the stall watchdog, and consumes reload
/// requests through the retry → reload → quarantine ladder. Returns the
/// number of rung-1 reloads + rung-2 quarantines it performed when `stop`
/// is raised.
///
/// Supervisor reloads stage CPU-native RMs (fault campaigns run on the
/// CPU data plane; a poisoned modelled-FPGA RM is out of reach anyway —
/// `LoadedRm::poison` skips it).
pub fn spawn_supervisor(
    env: SupervisorEnv,
    targets: Vec<SupervisorTarget>,
    stop: Arc<AtomicBool>,
) -> JoinHandle<u64> {
    std::thread::Builder::new()
        .name("fault-supervisor".into())
        .spawn(move || {
            let mut actions = 0u64;
            let mut states: Vec<TargetState> = targets
                .iter()
                .map(|t| TargetState {
                    reloads: 0,
                    last_beat: t.ctl.health.beat(),
                    last_change: Instant::now(),
                    stall_latched: false,
                    quarantined: false,
                })
                .collect();
            let stall_timeout = Duration::from_millis(env.faults.stall_timeout_ms.max(1));
            while !stop.load(Ordering::SeqCst) {
                for (t, st) in targets.iter().zip(states.iter_mut()) {
                    if st.quarantined {
                        continue;
                    }
                    // -- stall watchdog -----------------------------------
                    let beat = t.ctl.health.beat();
                    if beat != st.last_beat {
                        st.last_beat = beat;
                        st.last_change = Instant::now();
                        st.stall_latched = false;
                    } else if t.ctl.health.is_processing()
                        && st.last_change.elapsed() > stall_timeout
                        && !st.stall_latched
                    {
                        // Frozen beat while scoring: the partition is
                        // wedged. Latch so one stall records one event.
                        st.stall_latched = true;
                        t.ctl.faults.record(FaultEvent {
                            id: "-".into(),
                            pblock: t.pblock,
                            at_flit: t.ctl.swap.flits_seen(),
                            fault: "stall".into(),
                            action: "stall_detected".into(),
                            rung: 0,
                            latency_us: st.last_change.elapsed().as_micros() as u64,
                            checkpoint_flit: None,
                            detail: format!(
                                "no heartbeat for {} ms while processing",
                                st.last_change.elapsed().as_millis()
                            ),
                        });
                    }
                    // -- reload ladder ------------------------------------
                    let Some(req) = t.ctl.health.take_reload() else { continue };
                    let t0 = Instant::now();
                    st.reloads += 1;
                    if st.reloads > env.faults.max_reloads {
                        // Rung 2: the partition keeps corrupting itself
                        // through fresh RMs — stop trusting the region.
                        t.decoupler.quarantine();
                        st.quarantined = true;
                        actions += 1;
                        t.ctl.faults.record(FaultEvent {
                            id: req.fault_id,
                            pblock: t.pblock,
                            at_flit: t.ctl.swap.flits_seen(),
                            fault: "state_corrupt".into(),
                            action: "quarantined".into(),
                            rung: 2,
                            latency_us: t0.elapsed().as_micros() as u64,
                            checkpoint_flit: None,
                            detail: format!(
                                "{} reloads exhausted ({}); partition isolated for the \
                                 rest of the run",
                                env.faults.max_reloads, req.reason
                            ),
                        });
                        continue;
                    }
                    // Rung 1: bounded exponential backoff, then reload
                    // through the DFX path like a planned swap.
                    let backoff = env.faults.backoff_ms << (st.reloads - 1).min(16);
                    if backoff > 0 {
                        std::thread::sleep(Duration::from_millis(backoff));
                    }
                    stage_reload(&env, t, &req, st, t0, &mut actions);
                }
                std::thread::sleep(Duration::from_micros(200));
            }
            actions
        })
        .expect("spawn fault supervisor")
}

/// Stage one rung-1 reload for `t`: fresh RM, checkpoint restored into it
/// when one exists, scheduled at the partition's current flit (the service
/// loop is blocking on `pending_count`, so it lands at the next flit).
fn stage_reload(
    env: &SupervisorEnv,
    t: &SupervisorTarget,
    req: &ReloadRequest,
    st: &mut TargetState,
    t0: Instant,
    actions: &mut u64,
) {
    let at_flit = t.ctl.swap.flits_seen();
    let staged = env.dfx.stage(
        t.pblock,
        RmKind::Detector(t.kind),
        t.r,
        t.d,
        t.seed,
        &env.hyper,
        &t.warmup,
        None,
        t.quantize,
        at_flit,
        env.faults.dark_flits,
        env.policy,
        env.chunk,
        env.samples_per_sec,
        t.lanes,
    );
    match staged {
        Ok(mut swap) => {
            let mut checkpoint_flit = None;
            let mut detail = format!("fresh {} staged (attempt {})", swap.rm.describe(), st.reloads);
            if let Some(cp) = t.ctl.checkpoint.latest() {
                match restore_rm(&mut swap.rm, &cp.bytes) {
                    Ok(()) => {
                        swap.preserve_state = true;
                        checkpoint_flit = Some(cp.flit);
                        detail = format!(
                            "reloaded from checkpoint flit {} (attempt {})",
                            cp.flit, st.reloads
                        );
                    }
                    Err(e) => {
                        detail = format!(
                            "checkpoint restore failed ({e:#}); cold reload (attempt {})",
                            st.reloads
                        );
                    }
                }
            }
            t.ctl.swap.schedule(swap);
            *actions += 1;
            t.ctl.faults.record(FaultEvent {
                id: req.fault_id.clone(),
                pblock: t.pblock,
                at_flit,
                fault: "state_corrupt".into(),
                action: "reloaded".into(),
                rung: 1,
                latency_us: t0.elapsed().as_micros() as u64,
                checkpoint_flit,
                detail,
            });
        }
        Err(e) => {
            // A failed staging attempt still consumed a rung-1 strike:
            // repeated failures escalate to quarantine instead of looping.
            t.ctl.faults.record(FaultEvent {
                id: req.fault_id.clone(),
                pblock: t.pblock,
                at_flit,
                fault: "state_corrupt".into(),
                action: "reload_failed".into(),
                rung: 1,
                latency_us: t0.elapsed().as_micros() as u64,
                checkpoint_flit: None,
                detail: format!("staging failed: {e:#}"),
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::detectors::prng::Prng;
    use crate::fabric::pblock::LoadedRm;

    fn hyper() -> DetectorHyper {
        DetectorHyper { window: 16, bins: 8, w: 2, modulus: 32, k: 4 }
    }

    fn warmup(d: usize) -> Vec<f32> {
        let mut p = Prng::new(5);
        (0..32 * d).map(|_| p.gaussian() as f32).collect()
    }

    fn target(ctl: Arc<PblockCtl>, dec: Arc<Decoupler>) -> SupervisorTarget {
        SupervisorTarget {
            pblock: 1,
            ctl,
            decoupler: dec,
            kind: DetectorKind::Loda,
            r: 4,
            d: 3,
            seed: 7,
            warmup: warmup(3),
            lanes: 1,
            quantize: false,
        }
    }

    fn env(max_reloads: u32) -> SupervisorEnv {
        SupervisorEnv {
            dfx: DfxManager::default(),
            faults: FaultsCfg {
                max_reloads,
                backoff_ms: 0,
                stall_timeout_ms: 5,
                dark_flits: Some(1),
                ..Default::default()
            },
            hyper: hyper(),
            chunk: 16,
            samples_per_sec: 1e5,
            policy: DarkPolicy::Bypass,
        }
    }

    fn wait_for<F: Fn() -> bool>(cond: F) {
        let t0 = Instant::now();
        while !cond() && t0.elapsed() < Duration::from_secs(5) {
            std::thread::sleep(Duration::from_millis(1));
        }
        assert!(cond(), "condition not reached within 5 s");
    }

    #[test]
    fn reload_request_stages_swap_with_checkpoint_restore() {
        let ctl = Arc::new(PblockCtl::default());
        let dec = Arc::new(Decoupler::new());
        ctl.health.arm(4, 100);
        // Fabricate a checkpoint: a detector RM fed 32 samples.
        let mut rm = LoadedRm::build(
            RmKind::Detector(DetectorKind::Loda),
            4,
            3,
            7,
            &hyper(),
            &warmup(3),
            None,
            false,
            1,
        )
        .unwrap();
        if let LoadedRm::DetectorCpu { det } = &mut rm {
            let data = warmup(3);
            let mut out = vec![0f32; 32];
            det.update_batch(&data[..96], &mut out);
        }
        let bytes = crate::fabric::snapshot::snapshot_rm(&rm).unwrap();
        ctl.checkpoint
            .store(crate::fabric::snapshot::Checkpoint { flit: 2, samples: 32, bytes });
        for _ in 0..6 {
            ctl.swap.advance();
        }
        let stop = Arc::new(AtomicBool::new(false));
        let handle =
            spawn_supervisor(env(2), vec![target(Arc::clone(&ctl), Arc::clone(&dec))], Arc::clone(&stop));
        assert!(ctl.health.request_reload(ReloadRequest {
            fault_id: "t1".into(),
            at_flit: 6,
            reason: "test".into(),
        }));
        wait_for(|| ctl.swap.pending_count() > 0);
        stop.store(true, Ordering::SeqCst);
        assert_eq!(handle.join().unwrap(), 1);
        let swap = ctl.swap.try_take_due().expect("reload staged at current flit");
        assert_eq!(swap.at_flit, 6);
        assert!(swap.preserve_state, "checkpoint restore must skip the post-swap reset");
        assert_eq!(swap.dark_flits, 1);
        let evs = ctl.faults.take_events();
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].action, "reloaded");
        assert_eq!(evs[0].rung, 1);
        assert_eq!(evs[0].id, "t1");
        assert_eq!(evs[0].checkpoint_flit, Some(2));
        assert!(!dec.is_quarantined());
    }

    #[test]
    fn exhausted_reloads_escalate_to_quarantine() {
        let ctl = Arc::new(PblockCtl::default());
        let dec = Arc::new(Decoupler::new());
        ctl.health.arm(0, 100);
        let stop = Arc::new(AtomicBool::new(false));
        let handle =
            spawn_supervisor(env(1), vec![target(Arc::clone(&ctl), Arc::clone(&dec))], Arc::clone(&stop));
        // First request: rung 1 (cold reload, no checkpoint stored).
        ctl.health.request_reload(ReloadRequest {
            fault_id: "a".into(),
            at_flit: 0,
            reason: "nan".into(),
        });
        wait_for(|| ctl.swap.pending_count() > 0);
        // Second request exceeds max_reloads = 1: rung 2.
        ctl.health.request_reload(ReloadRequest {
            fault_id: "b".into(),
            at_flit: 1,
            reason: "nan again".into(),
        });
        wait_for(|| dec.is_quarantined());
        stop.store(true, Ordering::SeqCst);
        assert_eq!(handle.join().unwrap(), 2);
        assert!(!dec.is_enabled(), "quarantine must block future swaps");
        let evs = ctl.faults.take_events();
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0].action, "reloaded");
        assert_eq!(evs[0].checkpoint_flit, None, "no checkpoint -> cold reload");
        assert_eq!(evs[1].action, "quarantined");
        assert_eq!(evs[1].rung, 2);
        assert_eq!(evs[1].id, "b");
        // Quarantined targets are left alone afterwards.
        ctl.health.request_reload(ReloadRequest {
            fault_id: "c".into(),
            at_flit: 2,
            reason: "ignored".into(),
        });
        assert!(ctl.health.has_reload_request(), "supervisor no longer consumes requests");
    }

    #[test]
    fn watchdog_flags_processing_stall_but_not_inbox_wait() {
        let ctl = Arc::new(PblockCtl::default());
        let dec = Arc::new(Decoupler::new());
        ctl.health.arm(0, 100);
        let stop = Arc::new(AtomicBool::new(false));
        let handle =
            spawn_supervisor(env(2), vec![target(Arc::clone(&ctl), Arc::clone(&dec))], Arc::clone(&stop));
        // Idle (processing = false): however long the beat is frozen, the
        // watchdog must stay silent — blocked-on-inbox is healthy.
        std::thread::sleep(Duration::from_millis(30));
        assert!(ctl.faults.take_events().is_empty(), "inbox wait must not be flagged");
        // Wedge mid-processing: beat frozen with the flag raised.
        ctl.health.tick();
        ctl.health.set_processing(true);
        wait_for(|| {
            let evs = ctl.faults.take_events();
            if evs.is_empty() {
                return false;
            }
            assert_eq!(evs[0].action, "stall_detected");
            assert_eq!(evs[0].fault, "stall");
            true
        });
        // The beat moving again unlatches without further events.
        ctl.health.set_processing(false);
        ctl.health.tick();
        std::thread::sleep(Duration::from_millis(10));
        stop.store(true, Ordering::SeqCst);
        handle.join().unwrap();
    }
}
