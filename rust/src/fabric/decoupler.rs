//! DFX decoupler (paper §3.4): isolates a reconfigurable partition while
//! its RM is being swapped, so in-flight traffic never reaches
//! half-configured logic. Atomically toggled by the DFX manager; checked by
//! the pblock service loop on every flit — in burst mode the check runs
//! once per drained flit while filtering the backlog, so drop counting and
//! isolation semantics are identical across both drain strategies.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

#[derive(Debug, Default)]
pub struct Decoupler {
    decoupled: AtomicBool,
    /// Count of flits dropped while isolated (telemetry).
    dropped: AtomicU64,
}

impl Decoupler {
    pub fn new() -> Decoupler {
        Decoupler::default()
    }

    /// Isolate the partition (assert DECOUPLE).
    pub fn decouple(&self) {
        self.decoupled.store(true, Ordering::SeqCst);
    }

    /// Release the partition after reconfiguration + reset.
    pub fn recouple(&self) {
        self.decoupled.store(false, Ordering::SeqCst);
    }

    pub fn is_decoupled(&self) -> bool {
        let d = self.decoupled.load(Ordering::SeqCst);
        if d {
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
        d
    }

    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn toggles() {
        let d = Decoupler::new();
        assert!(!d.is_decoupled());
        d.decouple();
        assert!(d.is_decoupled());
        d.recouple();
        assert!(!d.is_decoupled());
    }

    #[test]
    fn counts_drops_while_isolated() {
        let d = Decoupler::new();
        d.decouple();
        for _ in 0..5 {
            assert!(d.is_decoupled());
        }
        assert_eq!(d.dropped(), 5);
        d.recouple();
        assert!(!d.is_decoupled());
        assert_eq!(d.dropped(), 5);
    }

    #[test]
    fn shared_across_threads() {
        let d = std::sync::Arc::new(Decoupler::new());
        let d2 = d.clone();
        let t = std::thread::spawn(move || {
            d2.decouple();
        });
        t.join().unwrap();
        assert!(d.is_decoupled());
    }
}
