//! DFX decoupler (paper §3.4): isolates a reconfigurable partition while
//! its RM is being swapped, so in-flight traffic never reaches
//! half-configured logic. Atomically toggled by the DFX manager; checked by
//! the pblock service loop on every flit — in burst mode the check runs
//! once per drained flit while filtering the backlog, so drop counting and
//! isolation semantics are identical across both drain strategies.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

#[derive(Debug)]
pub struct Decoupler {
    decoupled: AtomicBool,
    /// A shell can be built without decoupling IP for a region; such a
    /// pblock cannot be isolated, and the DFX manager refuses to swap it
    /// (half-configured logic would see live traffic). Enabled by default.
    enabled: AtomicBool,
    /// Count of flits dropped while isolated (telemetry).
    dropped: AtomicU64,
}

impl Default for Decoupler {
    fn default() -> Self {
        Decoupler {
            decoupled: AtomicBool::new(false),
            enabled: AtomicBool::new(true),
            dropped: AtomicU64::new(0),
        }
    }
}

impl Decoupler {
    pub fn new() -> Decoupler {
        Decoupler::default()
    }

    /// Model a shell with/without decoupling IP for this region.
    pub fn set_enabled(&self, enabled: bool) {
        self.enabled.store(enabled, Ordering::SeqCst);
    }

    pub fn is_enabled(&self) -> bool {
        self.enabled.load(Ordering::SeqCst)
    }

    /// Isolate the partition (assert DECOUPLE). No-op when the decoupler
    /// is disabled — callers must check [`Decoupler::is_enabled`] first.
    pub fn decouple(&self) {
        if self.is_enabled() {
            self.decoupled.store(true, Ordering::SeqCst);
        }
    }

    /// Release the partition after reconfiguration + reset.
    pub fn recouple(&self) {
        self.decoupled.store(false, Ordering::SeqCst);
    }

    pub fn is_decoupled(&self) -> bool {
        let d = self.decoupled.load(Ordering::SeqCst);
        if d {
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
        d
    }

    /// Explicitly charge one dropped flit to the telemetry counter (used by
    /// the DFX gate's dark window, where the drop decision is made without
    /// probing `is_decoupled`).
    pub fn count_drop(&self) {
        self.dropped.fetch_add(1, Ordering::Relaxed);
    }

    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn toggles() {
        let d = Decoupler::new();
        assert!(!d.is_decoupled());
        d.decouple();
        assert!(d.is_decoupled());
        d.recouple();
        assert!(!d.is_decoupled());
    }

    #[test]
    fn counts_drops_while_isolated() {
        let d = Decoupler::new();
        d.decouple();
        for _ in 0..5 {
            assert!(d.is_decoupled());
        }
        assert_eq!(d.dropped(), 5);
        d.recouple();
        assert!(!d.is_decoupled());
        assert_eq!(d.dropped(), 5);
    }

    #[test]
    fn disabled_decoupler_cannot_isolate() {
        let d = Decoupler::new();
        assert!(d.is_enabled());
        d.set_enabled(false);
        d.decouple();
        assert!(!d.is_decoupled(), "disabled decoupler must not isolate");
        d.set_enabled(true);
        d.decouple();
        assert!(d.is_decoupled());
    }

    #[test]
    fn shared_across_threads() {
        let d = std::sync::Arc::new(Decoupler::new());
        let d2 = d.clone();
        let t = std::thread::spawn(move || {
            d2.decouple();
        });
        t.join().unwrap();
        assert!(d.is_decoupled());
    }
}
