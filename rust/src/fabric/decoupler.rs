//! DFX decoupler (paper §3.4): isolates a reconfigurable partition while
//! its RM is being swapped, so in-flight traffic never reaches
//! half-configured logic. Atomically toggled by the DFX manager; checked by
//! the pblock service loop on every flit — in burst mode the check runs
//! once per drained flit while filtering the backlog, so drop counting and
//! isolation semantics are identical across both drain strategies.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

#[derive(Debug)]
pub struct Decoupler {
    decoupled: AtomicBool,
    /// A shell can be built without decoupling IP for a region; such a
    /// pblock cannot be isolated, and the DFX manager refuses to swap it
    /// (half-configured logic would see live traffic). Enabled by default.
    enabled: AtomicBool,
    /// Count of flits dropped while isolated (telemetry).
    dropped: AtomicU64,
    /// Latched by the fault supervisor's last escalation rung: the
    /// partition stays permanently isolated (`decoupled` held, `enabled`
    /// cleared so nothing can swap or recouple it back in) and downstream
    /// combines renormalize around it. Cleared only by
    /// [`Decoupler::lift_quarantine`] (session/run boundary).
    quarantined: AtomicBool,
}

impl Default for Decoupler {
    fn default() -> Self {
        Decoupler {
            decoupled: AtomicBool::new(false),
            enabled: AtomicBool::new(true),
            dropped: AtomicU64::new(0),
            quarantined: AtomicBool::new(false),
        }
    }
}

impl Decoupler {
    pub fn new() -> Decoupler {
        Decoupler::default()
    }

    /// Model a shell with/without decoupling IP for this region.
    pub fn set_enabled(&self, enabled: bool) {
        self.enabled.store(enabled, Ordering::SeqCst);
    }

    pub fn is_enabled(&self) -> bool {
        self.enabled.load(Ordering::SeqCst)
    }

    /// Isolate the partition (assert DECOUPLE). No-op when the decoupler
    /// is disabled — callers must check [`Decoupler::is_enabled`] first.
    pub fn decouple(&self) {
        if self.is_enabled() {
            self.decoupled.store(true, Ordering::SeqCst);
        }
    }

    /// Release the partition after reconfiguration + reset.
    pub fn recouple(&self) {
        self.decoupled.store(false, Ordering::SeqCst);
    }

    pub fn is_decoupled(&self) -> bool {
        let d = self.decoupled.load(Ordering::SeqCst);
        if d {
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
        d
    }

    /// Side-effect-free isolation probe — unlike [`Decoupler::is_decoupled`]
    /// this never charges the drop counter, so telemetry (the operator
    /// plane's snapshot) can poll it without perturbing drop accounting.
    pub fn is_isolated(&self) -> bool {
        self.decoupled.load(Ordering::SeqCst)
    }

    /// Explicitly charge one dropped flit to the telemetry counter (used by
    /// the DFX gate's dark window, where the drop decision is made without
    /// probing `is_decoupled`).
    pub fn count_drop(&self) {
        self.dropped.fetch_add(1, Ordering::Relaxed);
    }

    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Permanently isolate the partition (fault ladder rung 2). Order
    /// matters: the decoupler must assert DECOUPLE *before* it is disabled
    /// ([`Decoupler::decouple`] is a no-op once disabled), and disabling it
    /// afterwards blocks any staged swap from re-enabling the region.
    pub fn quarantine(&self) {
        self.decouple();
        self.set_enabled(false);
        self.quarantined.store(true, Ordering::SeqCst);
    }

    /// Side-effect-free quarantine probe — unlike [`Decoupler::is_decoupled`]
    /// this never charges the drop counter, so control-plane code (combo
    /// degradation, the service loop's reload wait) can poll it freely.
    pub fn is_quarantined(&self) -> bool {
        self.quarantined.load(Ordering::SeqCst)
    }

    /// Re-admit a quarantined partition (session/run boundary: the next
    /// episode gets a fresh RM, so the region is trustworthy again).
    pub fn lift_quarantine(&self) {
        self.quarantined.store(false, Ordering::SeqCst);
        self.set_enabled(true);
        self.recouple();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn toggles() {
        let d = Decoupler::new();
        assert!(!d.is_decoupled());
        d.decouple();
        assert!(d.is_decoupled());
        d.recouple();
        assert!(!d.is_decoupled());
    }

    #[test]
    fn counts_drops_while_isolated() {
        let d = Decoupler::new();
        d.decouple();
        for _ in 0..5 {
            assert!(d.is_decoupled());
        }
        assert_eq!(d.dropped(), 5);
        d.recouple();
        assert!(!d.is_decoupled());
        assert_eq!(d.dropped(), 5);
    }

    #[test]
    fn disabled_decoupler_cannot_isolate() {
        let d = Decoupler::new();
        assert!(d.is_enabled());
        d.set_enabled(false);
        d.decouple();
        assert!(!d.is_decoupled(), "disabled decoupler must not isolate");
        d.set_enabled(true);
        d.decouple();
        assert!(d.is_decoupled());
    }

    #[test]
    fn quarantine_latches_and_survives_recouple_attempts() {
        let d = Decoupler::new();
        d.quarantine();
        assert!(d.is_quarantined());
        assert!(d.is_decoupled(), "quarantine must isolate");
        assert!(!d.is_enabled(), "quarantine must block future swaps");
        // A probe never charges the drop counter.
        let before = d.dropped();
        for _ in 0..10 {
            assert!(d.is_quarantined());
        }
        assert_eq!(d.dropped(), before);
        d.lift_quarantine();
        assert!(!d.is_quarantined());
        assert!(d.is_enabled());
        assert!(!d.is_decoupled());
    }

    #[test]
    fn shared_across_threads() {
        let d = std::sync::Arc::new(Decoupler::new());
        let d2 = d.clone();
        let t = std::thread::spawn(move || {
            d2.decouple();
        });
        t.join().unwrap();
        assert!(d.is_decoupled());
    }
}
