//! DMA engines: host↔fabric stream endpoints (the blue blocks of Fig 6).
//!
//! An input DMA reads a row-major sample buffer and produces chunk flits; an
//! output DMA collects score flits back into a host buffer, unpadding via
//! the validity mask. Each pblock has its own fixed input DMA channel
//! (paper §3.3), so the same dataset fanned out to several pblocks is sent
//! once per channel, exactly like the board. Channels serving the same
//! stream share one host buffer (`Arc<Vec<f32>>`), and the flits they cut
//! carry shared `Arc<[f32]>` payloads — the samples are copied exactly
//! once, at chunking time.

use anyhow::{bail, Result};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use super::message::Flit;
use crate::config::NonFinite;
use crate::data::stream::ChunkStream;

/// Input DMA: streams `data` ([n, d] row-major) as padded chunks.
///
/// Ingress hygiene: every valid row is screened for non-finite values
/// (NaN/±Inf) under the `[fabric] non_finite` policy — corrupt host input
/// is caught at the one point all partitions share, before it can poison
/// any detector window. `Error` (the default) fails the stream naming the
/// first offending sample; `Clamp` sanitizes in place (NaN → 0.0,
/// ±Inf → ±f32::MAX) and counts the repairs in [`DmaReport::clamped`].
pub struct InputDma;

impl InputDma {
    pub fn spawn(
        name: String,
        data: Arc<Vec<f32>>,
        d: usize,
        chunk: usize,
        policy: NonFinite,
        tx: Sender<Flit>,
    ) -> JoinHandle<Result<DmaReport>> {
        std::thread::Builder::new()
            .name(name.clone())
            .spawn(move || {
                let mut report = DmaReport::default();
                for mut flit in ChunkStream::new(&data, d, chunk) {
                    let valid = flit.n_valid * d;
                    if let Some(bad) = flit.data[..valid].iter().position(|v| !v.is_finite()) {
                        match policy {
                            NonFinite::Error => bail!(
                                "{name}: non-finite input sample {} at flit seq {}, row {}, \
                                 col {} — reject policy is `non_finite = \"error\"`; set \
                                 `non_finite = \"clamp\"` under [fabric] to sanitize at ingress",
                                flit.data[bad],
                                flit.seq,
                                bad / d,
                                bad % d
                            ),
                            NonFinite::Clamp => {
                                let mut fixed: Vec<f32> = flit.data.to_vec();
                                for v in fixed[..valid].iter_mut() {
                                    if !v.is_finite() {
                                        *v = if v.is_nan() {
                                            0.0
                                        } else if v.is_sign_positive() {
                                            f32::MAX
                                        } else {
                                            f32::MIN
                                        };
                                        report.clamped += 1;
                                    }
                                }
                                flit.data = fixed.into();
                            }
                        }
                    }
                    report.flits += 1;
                    report.bytes += (flit.data.len() * 4) as u64;
                    report.samples += flit.n_valid as u64;
                    if tx.send(flit).is_err() {
                        break; // fabric tore down mid-stream
                    }
                }
                Ok(report)
            })
            .expect("spawn input dma")
    }
}

/// Output DMA: collects score flits into a contiguous host vector.
pub struct OutputDma;

/// Unpad one flit into a host buffer: keep only the valid leading rows
/// (`d = data.len() / mask.len()`). Shared by the output DMAs and the
/// session server's score delivery, so both unframe identically.
pub fn unpad_into(flit: &Flit, out: &mut Vec<f32>) {
    let d = if flit.mask.is_empty() { 1 } else { flit.data.len() / flit.mask.len() };
    out.extend_from_slice(&flit.data[..flit.n_valid * d]);
}

impl OutputDma {
    pub fn spawn(name: String, rx: Receiver<Flit>) -> JoinHandle<(Vec<f32>, DmaReport)> {
        std::thread::Builder::new()
            .name(name)
            .spawn(move || {
                let mut out = Vec::new();
                let mut report = DmaReport::default();
                for flit in rx.iter() {
                    report.flits += 1;
                    report.bytes += (flit.data.len() * 4) as u64;
                    report.samples += flit.n_valid as u64;
                    unpad_into(&flit, &mut out);
                    if flit.last {
                        break;
                    }
                }
                (out, report)
            })
            .expect("spawn output dma")
    }
}

/// Transfer statistics per DMA channel.
#[derive(Clone, Copy, Debug, Default)]
pub struct DmaReport {
    pub flits: u64,
    pub bytes: u64,
    pub samples: u64,
    /// Non-finite input values sanitized at ingress (`non_finite = "clamp"`).
    pub clamped: u64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fabric::message::Port;

    #[test]
    fn roundtrip_through_both_dmas() {
        let data: Vec<f32> = (0..10).map(|i| i as f32).collect(); // 5 samples d=2
        let (tx, rx) = Port::link();
        let input = InputDma::spawn("in".into(), Arc::new(data.clone()), 2, 4, NonFinite::Error, tx);
        let output = OutputDma::spawn("out".into(), rx);
        let in_report = input.join().unwrap().unwrap();
        let (collected, out_report) = output.join().unwrap();
        assert_eq!(collected, data); // unpadded
        assert_eq!(in_report.samples, 5);
        assert_eq!(out_report.samples, 5);
        assert_eq!(in_report.flits, 2); // 4 + 1(padded)
    }

    #[test]
    fn output_dma_stops_at_last() {
        let (tx, rx) = Port::link();
        let output = OutputDma::spawn("out".into(), rx);
        tx.send(crate::fabric::message::score_chunk(0, vec![1.0, 2.0], vec![1.0, 1.0], 2, false))
            .unwrap();
        tx.send(crate::fabric::message::score_chunk(1, vec![3.0, 0.0], vec![1.0, 0.0], 1, true))
            .unwrap();
        let (collected, report) = output.join().unwrap();
        assert_eq!(collected, vec![1.0, 2.0, 3.0]);
        assert_eq!(report.flits, 2);
    }

    #[test]
    fn output_dma_unpads_flits_with_shared_masks() {
        // Several flits sharing one Arc mask (the zero-copy fan-out case)
        // unpad exactly like flits with private masks.
        let mask: Arc<[f32]> = vec![1.0, 1.0, 0.0].into();
        let (tx, rx) = Port::link();
        let output = OutputDma::spawn("out".into(), rx);
        for seq in 0..3u64 {
            let base = seq as f32 * 10.0;
            tx.send(crate::fabric::message::score_chunk(
                seq,
                vec![base, base + 1.0, -1.0], // padding row must be dropped
                mask.clone(),
                2,
                seq == 2,
            ))
            .unwrap();
        }
        let (collected, report) = output.join().unwrap();
        assert_eq!(collected, vec![0.0, 1.0, 10.0, 11.0, 20.0, 21.0]);
        assert_eq!(report.flits, 3);
        assert_eq!(report.samples, 6);
    }

    #[test]
    fn input_dma_flits_share_the_full_mask() {
        let data = vec![0f32; 8 * 2]; // 8 samples, chunk 4 → 2 full chunks
        let (tx, rx) = Port::link();
        let input = InputDma::spawn("in".into(), Arc::new(data), 2, 4, NonFinite::Error, tx);
        input.join().unwrap().unwrap();
        let flits: Vec<Flit> = rx.iter().collect();
        assert_eq!(flits.len(), 2);
        assert!(Arc::ptr_eq(&flits[0].mask, &flits[1].mask));
    }

    #[test]
    fn input_dma_survives_dropped_consumer() {
        let data = vec![0f32; 100 * 3];
        let (tx, rx) = Port::link();
        drop(rx);
        let input = InputDma::spawn("in".into(), Arc::new(data), 3, 8, NonFinite::Error, tx);
        let report = input.join().unwrap().unwrap(); // must not panic
        assert!(report.flits <= 1);
    }

    #[test]
    fn error_policy_rejects_non_finite_input_naming_the_sample() {
        // Sample 5 (row 1 of flit seq 1), col 1 is NaN.
        let mut data = vec![0f32; 8 * 2];
        data[5 * 2 + 1] = f32::NAN;
        let (tx, rx) = Port::link();
        let input = InputDma::spawn("in".into(), Arc::new(data), 2, 4, NonFinite::Error, tx);
        let err = input.join().unwrap().unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("seq 1") && msg.contains("row 1") && msg.contains("col 1"), "{msg}");
        // The clean first flit was still delivered before the stop.
        assert_eq!(rx.iter().count(), 1);
    }

    #[test]
    fn clamp_policy_sanitizes_and_counts() {
        let mut data = vec![1.0f32; 6 * 2];
        data[2] = f32::NAN;
        data[7] = f32::INFINITY;
        data[9] = f32::NEG_INFINITY;
        let (tx, rx) = Port::link();
        let input = InputDma::spawn("in".into(), Arc::new(data), 2, 4, NonFinite::Clamp, tx);
        let flits: Vec<Flit> = rx.iter().collect();
        let report = input.join().unwrap().unwrap();
        assert_eq!(report.clamped, 3);
        assert_eq!(flits[0].data[2], 0.0);
        assert_eq!(flits[0].data[7], f32::MAX);
        assert_eq!(flits[1].data[1], f32::MIN);
        // Every surviving value is finite.
        assert!(flits.iter().all(|f| f.data.iter().all(|v| v.is_finite())));
    }

    #[test]
    fn clamp_policy_leaves_clean_streams_untouched() {
        let data: Vec<f32> = (0..12).map(|i| i as f32).collect();
        let (tx, rx) = Port::link();
        let input = InputDma::spawn("in".into(), Arc::new(data.clone()), 2, 4, NonFinite::Clamp, tx);
        let report = input.join().unwrap().unwrap();
        assert_eq!(report.clamped, 0);
        let mut collected = Vec::new();
        for f in rx.iter() {
            unpad_into(&f, &mut collected);
        }
        assert_eq!(collected, data);
    }
}
