//! Checkpoint codec for detector window state.
//!
//! Everything dynamic in a CPU detector RM lives in its
//! [`SlidingCounts`] window (parameters and derived caches rebuild
//! deterministically from the seed + warm-up prefix), so a checkpoint is
//! just that window serialized — for a multi-lane RM, one window per lane.
//! The codec is hand-rolled (no serde in this tree): a fixed little-endian
//! layout behind a magic/version header, bounds-checked on the way back in
//! and shape-checked against the live RM before a single value is written,
//! so a truncated or mismatched snapshot can never half-restore a window.
//!
//! The fault supervisor uses this for rung 1 of its escalation ladder: the
//! service loop stores a [`Checkpoint`] into the partition's
//! [`CheckpointSlot`] every `checkpoint_every_flits` healthy flits, and a
//! corruption-triggered RM reload restores the latest checkpoint into the
//! staged replacement so the partition resumes **bit-identically** from the
//! checkpointed flit instead of cold-starting an empty window.

use anyhow::{bail, Context, Result};
use std::sync::Mutex;

use super::pblock::LoadedRm;
use crate::detectors::window::SlidingCounts;

/// Snapshot header magic ("fSEAD SNaPshot").
const MAGIC: [u8; 4] = *b"FSNP";
/// Layout version; bump on any wire-format change. Version 2 prefixes every
/// window section with its byte length, so a corrupted stream is refused at
/// the section boundary instead of being misread as window data.
const VERSION: u8 = 2;

/// Variant tags following the header.
const TAG_SINGLE: u8 = 1;
const TAG_LANES: u8 = 2;

// ---------------------------------------------------------------------------
// Little-endian wire helpers
// ---------------------------------------------------------------------------

pub(crate) struct Writer {
    pub(crate) buf: Vec<u8>,
}

impl Writer {
    pub(crate) fn new() -> Writer {
        Writer { buf: Vec::new() }
    }

    pub(crate) fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub(crate) fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub(crate) fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub(crate) fn put_f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub(crate) fn put_i32_slice(&mut self, vs: &[i32]) {
        self.buf.reserve(vs.len() * 4);
        for v in vs {
            self.buf.extend_from_slice(&v.to_le_bytes());
        }
    }
}

pub(crate) struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub(crate) fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    pub(crate) fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self.pos.checked_add(n).context("snapshot length overflow")?;
        if end > self.buf.len() {
            bail!("snapshot truncated: wanted {n} bytes at offset {}", self.pos);
        }
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    pub(crate) fn get_u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub(crate) fn get_u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub(crate) fn get_u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub(crate) fn get_f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub(crate) fn get_i32_vec(&mut self, n: usize) -> Result<Vec<i32>> {
        let raw = self.take(n.checked_mul(4).context("snapshot length overflow")?)?;
        Ok(raw.chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    pub(crate) fn done(&self) -> bool {
        self.pos == self.buf.len()
    }

    /// Split off a sub-reader over exactly the next `n` bytes (a
    /// length-checked section).
    pub(crate) fn section(&mut self, n: usize) -> Result<Reader<'a>> {
        Ok(Reader::new(self.take(n)?))
    }
}

// ---------------------------------------------------------------------------
// Window <-> wire
// ---------------------------------------------------------------------------

fn write_window(w: &mut Writer, sc: &SlidingCounts) {
    w.put_u32(sc.rows() as u32);
    w.put_u32(sc.width() as u32);
    w.put_u32(sc.window() as u32);
    w.put_u32(sc.pos() as u32);
    w.put_u64(sc.n());
    w.put_f32(sc.log2_denom());
    w.put_i32_slice(sc.counts());
    w.put_i32_slice(sc.ring());
}

/// Write one window as a length-prefixed section: `[u32 len][payload]`.
fn write_window_section(w: &mut Writer, sc: &SlidingCounts) {
    let mut body = Writer::new();
    write_window(&mut body, sc);
    w.put_u32(body.buf.len() as u32);
    w.buf.extend_from_slice(&body.buf);
}

/// Read one length-prefixed window section. The declared length must cover
/// exactly one window payload — too short and the payload read fails inside
/// the section, too long and the leftover is refused here — so a corrupted
/// length can never make the parser misread a neighbouring section.
fn read_window_section(r: &mut Reader<'_>, sc: &mut SlidingCounts) -> Result<()> {
    let len = r.get_u32()? as usize;
    let mut sec = r.section(len)?;
    read_window_into(&mut sec, sc)?;
    if !sec.done() {
        bail!("window section length disagrees with its payload — snapshot is corrupt");
    }
    Ok(())
}

fn read_window_into(r: &mut Reader<'_>, sc: &mut SlidingCounts) -> Result<()> {
    let rows = r.get_u32()? as usize;
    let width = r.get_u32()? as usize;
    let window = r.get_u32()? as usize;
    if (rows, width, window) != (sc.rows(), sc.width(), sc.window()) {
        bail!(
            "snapshot shape [{rows}×{width}, window {window}] does not match the live window \
             [{}×{}, window {}] — the RM it was taken from had a different configuration",
            sc.rows(),
            sc.width(),
            sc.window()
        );
    }
    let pos = r.get_u32()? as usize;
    let n = r.get_u64()?;
    let log2_denom = r.get_f32()?;
    let counts = r.get_i32_vec(rows * width)?;
    let ring = r.get_i32_vec(rows * window)?;
    sc.load(&counts, &ring, pos, n, log2_denom).map_err(anyhow::Error::new)
}

// ---------------------------------------------------------------------------
// RM <-> wire
// ---------------------------------------------------------------------------

/// Serialize the dynamic state of a CPU detector RM. `None` for RM variants
/// with no host-visible window state (empty, bypass, FPGA artifacts — the
/// device owns their state).
pub fn snapshot_rm(rm: &LoadedRm) -> Option<Vec<u8>> {
    let mut w = Writer::new();
    w.buf.extend_from_slice(&MAGIC);
    w.put_u8(VERSION);
    match rm {
        LoadedRm::DetectorCpu { det } => {
            let sc = det.window_state()?;
            w.put_u8(TAG_SINGLE);
            write_window_section(&mut w, sc);
        }
        LoadedRm::DetectorCpuLanes { lanes, .. } => {
            w.put_u8(TAG_LANES);
            w.put_u32(lanes.len() as u32);
            for lane in lanes {
                let sc = lane.det()?.window_state()?;
                write_window_section(&mut w, sc);
            }
        }
        _ => return None,
    }
    Some(w.buf)
}

/// Restore a snapshot into `rm`. The target must have the same variant and
/// window shape the snapshot was taken from (same detector kind / r /
/// hyper-parameters / lane layout); anything else is refused before any
/// state is modified — validation happens window-by-window through
/// [`SlidingCounts::load`], which rejects rather than partially applies.
pub fn restore_rm(rm: &mut LoadedRm, bytes: &[u8]) -> Result<()> {
    let mut r = Reader::new(bytes);
    if r.take(4)? != MAGIC {
        bail!("not a window snapshot (bad magic)");
    }
    let version = r.get_u8()?;
    if version != VERSION {
        bail!("unsupported snapshot version {version} (this build writes {VERSION})");
    }
    let tag = r.get_u8()?;
    match (tag, rm) {
        (TAG_SINGLE, LoadedRm::DetectorCpu { det }) => {
            let sc = det
                .window_state_mut()
                .context("detector exposes no window state to restore into")?;
            read_window_section(&mut r, sc)?;
        }
        (TAG_LANES, LoadedRm::DetectorCpuLanes { lanes, .. }) => {
            let n = r.get_u32()? as usize;
            if n != lanes.len() {
                bail!(
                    "snapshot has {n} lane window(s), the live RM has {} — lane layouts differ",
                    lanes.len()
                );
            }
            for (li, lane) in lanes.iter_mut().enumerate() {
                let sc = lane
                    .det_mut()
                    .and_then(|d| d.window_state_mut())
                    .with_context(|| format!("lane {li} exposes no window state"))?;
                read_window_section(&mut r, sc)
                    .with_context(|| format!("restoring lane {li}"))?;
            }
        }
        (TAG_SINGLE | TAG_LANES, rm) => bail!(
            "snapshot variant does not match the live RM ({}) — it was taken from a \
             different RM layout",
            rm.describe()
        ),
        (other, _) => bail!("unknown snapshot variant tag {other}"),
    }
    if !r.done() {
        bail!("snapshot has trailing bytes — corrupt or from a different layout");
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Per-partition checkpoint slot
// ---------------------------------------------------------------------------

/// One stored checkpoint: the RM's window state after `flit` input flits of
/// the current stream were fully processed.
#[derive(Clone, Debug)]
pub struct Checkpoint {
    /// Input flits fully processed when the snapshot was taken.
    pub flit: u64,
    /// Valid samples scored when the snapshot was taken.
    pub samples: u64,
    /// Serialized window state ([`snapshot_rm`]).
    pub bytes: Vec<u8>,
}

/// Latest-checkpoint mailbox on a partition's control surface: the service
/// loop stores, the fault supervisor reads when staging a recovery reload.
/// Single-slot by design — recovery always wants the most recent healthy
/// state, and a bounded slot can never grow with stream length.
#[derive(Default)]
pub struct CheckpointSlot {
    latest: Mutex<Option<Checkpoint>>,
}

impl CheckpointSlot {
    /// Replace the stored checkpoint.
    pub fn store(&self, cp: Checkpoint) {
        *self.latest.lock().unwrap() = Some(cp);
    }

    /// The most recent checkpoint, if any.
    pub fn latest(&self) -> Option<Checkpoint> {
        self.latest.lock().unwrap().clone()
    }

    /// Drop the stored checkpoint (stream/episode boundary: a checkpoint
    /// from one stream must never restore into another).
    pub fn clear(&self) {
        *self.latest.lock().unwrap() = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DetectorHyper, RmKind};
    use crate::detectors::prng::Prng;
    use crate::detectors::{Detector, DetectorKind};
    use crate::ensemble::lanes::LaneInput;

    fn hyper() -> DetectorHyper {
        DetectorHyper { window: 16, bins: 8, w: 2, modulus: 32, k: 4 }
    }

    fn stream(n: usize, d: usize, seed: u64) -> Vec<f32> {
        let mut p = Prng::new(seed);
        (0..n * d).map(|_| p.gaussian() as f32).collect()
    }

    fn rm(kind: DetectorKind, r: usize, seed: u64, warmup: &[f32], lanes: usize) -> LoadedRm {
        LoadedRm::build(RmKind::Detector(kind), r, 3, seed, &hyper(), warmup, None, false, lanes)
            .unwrap()
    }

    fn feed(rm: &mut LoadedRm, data: &[f32]) -> Vec<f32> {
        match rm {
            LoadedRm::DetectorCpu { det } => {
                let n = data.len() / det.d();
                let mut out = vec![0f32; n];
                det.update_batch(data, &mut out);
                out
            }
            LoadedRm::DetectorCpuLanes { lanes, d, .. } => {
                let n = data.len() / *d;
                let input = LaneInput::Rows(std::sync::Arc::new(data.to_vec()));
                crate::ensemble::lanes::score_inline(lanes, &input, n, usize::MAX).unwrap();
                let mut out = vec![0f32; n];
                crate::ensemble::lanes::merge_lanes_into(lanes, &mut out);
                out
            }
            _ => panic!("not a CPU detector RM"),
        }
    }

    #[test]
    fn roundtrip_resumes_bit_identically() {
        let data = stream(64, 3, 1);
        for kind in DetectorKind::ALL {
            // Reference: one uninterrupted stream.
            let mut a = rm(kind, 4, 7, &data[..30], 1);
            let want = feed(&mut a, &data);
            // Snapshot mid-stream, restore into a fresh RM, resume.
            let mut b = rm(kind, 4, 7, &data[..30], 1);
            feed(&mut b, &data[..32 * 3]);
            let snap = snapshot_rm(&b).expect("CPU detector RMs snapshot");
            let mut c = rm(kind, 4, 7, &data[..30], 1);
            restore_rm(&mut c, &snap).unwrap();
            let tail = feed(&mut c, &data[32 * 3..]);
            assert_eq!(&tail[..], &want[32..], "{kind:?} restored RM must resume bit-identically");
        }
    }

    #[test]
    fn roundtrip_covers_lane_arrays() {
        let data = stream(48, 3, 2);
        let mut a = rm(DetectorKind::Loda, 5, 9, &data[..30], 2);
        let want = feed(&mut a, &data);
        let mut b = rm(DetectorKind::Loda, 5, 9, &data[..30], 2);
        feed(&mut b, &data[..24 * 3]);
        let snap = snapshot_rm(&b).unwrap();
        let mut c = rm(DetectorKind::Loda, 5, 9, &data[..30], 2);
        restore_rm(&mut c, &snap).unwrap();
        let tail = feed(&mut c, &data[24 * 3..]);
        assert_eq!(&tail[..], &want[24..], "per-lane windows must restore independently");
    }

    #[test]
    fn shape_mismatch_is_refused() {
        let data = stream(32, 3, 3);
        let src = rm(DetectorKind::Loda, 4, 7, &data[..30], 1);
        let snap = snapshot_rm(&src).unwrap();
        // Different r → different window rows.
        let mut wrong_r = rm(DetectorKind::Loda, 3, 7, &data[..30], 1);
        assert!(restore_rm(&mut wrong_r, &snap).is_err());
        // Different lane layout.
        let mut wrong_lanes = rm(DetectorKind::Loda, 4, 7, &data[..30], 2);
        assert!(restore_rm(&mut wrong_lanes, &snap).is_err());
        // Non-detector RM.
        let mut bypass = LoadedRm::BypassNative;
        assert!(restore_rm(&mut bypass, &snap).is_err());
        assert!(snapshot_rm(&bypass).is_none());
    }

    #[test]
    fn truncated_or_corrupt_bytes_are_refused() {
        let data = stream(32, 3, 4);
        let src = rm(DetectorKind::RsHash, 3, 5, &data[..30], 1);
        let snap = snapshot_rm(&src).unwrap();
        let mut dst = rm(DetectorKind::RsHash, 3, 5, &data[..30], 1);
        // Every strict prefix must be refused with a named error, never a
        // panic — the codec is length-checked end to end.
        for cut in 0..snap.len() {
            assert!(restore_rm(&mut dst, &snap[..cut]).is_err(), "cut at {cut} must fail");
        }
        let mut bad_magic = snap.clone();
        bad_magic[0] ^= 0xFF;
        assert!(restore_rm(&mut dst, &bad_magic).is_err());
        let mut bad_version = snap.clone();
        bad_version[4] = 99;
        assert!(restore_rm(&mut dst, &bad_version).is_err());
        let mut trailing = snap.clone();
        trailing.push(0);
        assert!(restore_rm(&mut dst, &trailing).is_err());
        // Section length header lies (bytes 6..10 on a single-window
        // snapshot): too long reads past the end, too short leaves a
        // truncated payload plus trailing bytes. Both must be refused.
        let mut too_long = snap.clone();
        too_long[6..10].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(restore_rm(&mut dst, &too_long).is_err());
        let declared = u32::from_le_bytes(snap[6..10].try_into().unwrap());
        let mut too_short = snap.clone();
        too_short[6..10].copy_from_slice(&(declared - 1).to_le_bytes());
        assert!(restore_rm(&mut dst, &too_short).is_err());
        // Sanity: the untouched snapshot still restores after all refusals.
        restore_rm(&mut dst, &snap).unwrap();
    }

    #[test]
    fn lane_snapshot_cut_sweep_is_refused() {
        let data = stream(32, 3, 6);
        let src = rm(DetectorKind::Loda, 4, 5, &data[..30], 2);
        let snap = snapshot_rm(&src).unwrap();
        let mut dst = rm(DetectorKind::Loda, 4, 5, &data[..30], 2);
        for cut in 0..snap.len() {
            assert!(restore_rm(&mut dst, &snap[..cut]).is_err(), "cut at {cut} must fail");
        }
        restore_rm(&mut dst, &snap).unwrap();
    }

    #[test]
    fn checkpoint_slot_keeps_latest_and_clears() {
        let slot = CheckpointSlot::default();
        assert!(slot.latest().is_none());
        slot.store(Checkpoint { flit: 4, samples: 64, bytes: vec![1] });
        slot.store(Checkpoint { flit: 8, samples: 128, bytes: vec![2] });
        let cp = slot.latest().unwrap();
        assert_eq!((cp.flit, cp.samples, cp.bytes), (8, 128, vec![2]));
        slot.clear();
        assert!(slot.latest().is_none());
    }
}
