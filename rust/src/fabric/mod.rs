//! The composable fabric — the paper's system contribution (§3.3).
//!
//! Reconfigurable pblocks hold RMs (detector / bypass / combo), AXI-stream
//! switches route chunked streams between DMAs, pblocks and combos under a
//! register-programmed crossbar, and the DFX manager swaps RMs at run time
//! — between runs ([`reconfig`]) or in flight while the fabric is
//! streaming ([`hotswap`]: quiesce through the decoupler, dark-window
//! accounting, adaptive reconfiguration controller).
//!
//! Two deployments share this data plane: the one-shot batch pass
//! ([`Fabric::run`]) and the persistent multi-session streaming service
//! ([`server::FabricServer`], `fsead serve`), whose resident partition
//! workers drain the same service loops through bounded session inboxes —
//! in-process through [`server::Session`], or across the wire through the
//! [`net`] frame protocol (`fsead net`) — optionally sharded across worker
//! processes by the fault-tolerant session [`router`] (`fsead route`).

pub mod combo;
pub mod decoupler;
pub mod dma;
pub mod faults;
pub mod hotswap;
pub mod message;
pub mod net;
pub mod net_client;
pub mod operator;
pub mod pblock;
pub mod reconfig;
pub mod router;
pub mod score_sink;
pub mod server;
pub mod session_store;
pub mod snapshot;
pub mod supervisor;
pub mod switch;
pub mod topology;
pub mod worker_pool;

pub use faults::FaultEvent;
pub use hotswap::SwapEvent;
pub use message::{Flit, FlitSource, Port};
pub use net::{NetError, NetServer};
pub use net_client::{NetClient, NetClose, NetStatus};
pub use operator::{
    FabricSnapshot, OperatorError, OperatorServer, PartitionTelemetry, ServerTelemetry,
    SessionTelemetry,
};
pub use router::{Router, RouterSnapshot, RouterStats};
pub use score_sink::ScoreSink;
pub use server::{AdmitError, FabricServer, ServeError, Session, SessionSpec};
pub use session_store::{SessionStore, SessionTicket};
pub use worker_pool::{WorkerHealth, WorkerInfo, WorkerPool};
pub use switch::AxiSwitch;
pub use topology::{pblock_seed, Fabric};
