//! The composable fabric — the paper's system contribution (§3.3).
//!
//! Reconfigurable pblocks hold RMs (detector / bypass / combo), AXI-stream
//! switches route chunked streams between DMAs, pblocks and combos under a
//! register-programmed crossbar, and the DFX manager swaps RMs at run time
//! — between runs ([`reconfig`]) or in flight while the fabric is
//! streaming ([`hotswap`]: quiesce through the decoupler, dark-window
//! accounting, adaptive reconfiguration controller).

pub mod combo;
pub mod decoupler;
pub mod dma;
pub mod hotswap;
pub mod message;
pub mod pblock;
pub mod reconfig;
pub mod switch;
pub mod topology;

pub use hotswap::SwapEvent;
pub use message::{Flit, Port};
pub use switch::AxiSwitch;
pub use topology::Fabric;
