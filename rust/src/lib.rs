//! # fSEAD — composable streaming ensemble anomaly detection
//!
//! Reproduction of "fSEAD: a Composable FPGA-based Streaming Ensemble
//! Anomaly Detection Library" (Lou, Boland, Leong — ACM TRETS 2024) as a
//! three-layer rust + JAX + Pallas system. See `DESIGN.md` for the full
//! FPGA → software mapping and the experiment index.
//!
//! Layer map:
//! - **L1/L2** (build time, python): Pallas detector front-end kernels and
//!   the JAX streaming models, AOT-lowered to `artifacts/*.hlo.txt`.
//! - **L3** (this crate): the composable fabric — AXI-stream switches,
//!   reconfigurable pblocks, DMA endpoints, combo blocks, the DFX manager —
//!   plus the CPU baseline detectors, dataset substrate, hardware models
//!   and the experiment harness that regenerates every paper table/figure.
//!
//! The PJRT "FPGA device" is confined to a single service thread
//! ([`runtime`]); pblocks talk to it via channels, so python never sits on
//! the request path and `xla`'s `!Send` types never cross threads.

pub mod combine;
pub mod config;
pub mod data;
pub mod detectors;
pub mod ensemble;
pub mod exp;
pub mod fabric;
pub mod hw;
pub mod metrics;
pub mod runtime;
pub mod testutil;

/// Paper Table 4 hyper-parameters (shared with `python/compile/manifest.py`).
pub mod defaults {
    /// Sliding-window length W.
    pub const WINDOW: usize = 128;
    /// Loda histogram bins.
    pub const LODA_BINS: usize = 20;
    /// CMS rows w (hash functions per sketch).
    pub const CMS_ROWS: usize = 2;
    /// CMS table width (power of two).
    pub const CMS_MOD: usize = 128;
    /// xStream projection size K.
    pub const XSTREAM_K: usize = 20;
    /// Streaming chunk size C per executable invocation.
    pub const CHUNK: usize = 256;
    /// Paper Table 7: sub-detectors per pblock (sized for RP-3).
    pub const PBLOCK_R_LODA: usize = 35;
    pub const PBLOCK_R_RSHASH: usize = 25;
    pub const PBLOCK_R_XSTREAM: usize = 20;
    /// Number of detector pblocks / combo pblocks in the prototype fabric.
    pub const NUM_AD_PBLOCKS: usize = 7;
    pub const NUM_COMBO_PBLOCKS: usize = 3;
    /// FPGA clock (paper §4.4).
    pub const FPGA_CLOCK_HZ: f64 = 188.0e6;
}
