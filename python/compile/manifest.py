"""Artifact manifest: the single source of truth for which AOT variants exist.

Mirrors paper Table 4 hyper-parameters:
  window W=128, Loda bins B=20, CMS rows w=2 (Loda uses a 1-row histogram),
  CMS width MOD=128, xStream projection size K=20.

Per-pblock ensemble sizes follow paper Table 7: 35 Loda / 25 RS-Hash /
20 xStream sub-detectors fit the smallest pblock (RP-3).

The rust coordinator parses ``artifacts/manifest.txt`` (one line per
artifact, ``key=value`` tokens) — keep that format stable.
"""

from dataclasses import dataclass, field


# -- paper Table 4 defaults ------------------------------------------------
WINDOW = 128          # sliding-window length W
LODA_BINS = 20        # histogram bins
CMS_ROWS = 2          # w: hash functions per CMS
CMS_MOD = 128         # CMS table width (power of two)
XSTREAM_K = 20        # xStream projection size
CHUNK = 256           # streaming chunk size C per executable invocation

# paper Table 7: sub-detectors per pblock (sized for the smallest pblock RP-3)
PBLOCK_R = {"loda": 35, "rshash": 25, "xstream": 20}

# paper Table 3 dataset dimensionalities: cardio=21, shuttle=9, smtp3/http3=3
DATASET_DIMS = (3, 9, 21)


@dataclass(frozen=True)
class Variant:
    """One AOT artifact = one 'reconfigurable module bitstream'."""

    kind: str                 # loda | rshash | xstream | bypass | combo
    d: int = 0                # input feature dimension (0 for combos)
    r: int = 0                # ensemble size within the pblock
    chunk: int = CHUNK
    window: int = WINDOW
    bins: int = LODA_BINS
    w: int = CMS_ROWS
    mod: int = CMS_MOD
    k: int = XSTREAM_K
    combo: str = ""           # avg | max | wavg | or | vote
    quantize: bool = True     # Q16.16 score quantisation (ap_fixed<32,16>)

    @property
    def name(self) -> str:
        if self.kind == "bypass":
            return f"bypass_d{self.d}"
        if self.kind == "combo":
            return f"combo_{self.combo}"
        q = "" if self.quantize else "_f32"
        return f"{self.kind}_d{self.d}_r{self.r}{q}"

    def manifest_line(self) -> str:
        toks = [
            f"name={self.name}",
            f"kind={self.kind}",
            f"d={self.d}",
            f"r={self.r}",
            f"chunk={self.chunk}",
            f"window={self.window}",
            f"bins={self.bins}",
            f"w={self.w}",
            f"mod={self.mod}",
            f"k={self.k}",
            f"combo={self.combo or '-'}",
            f"quantize={int(self.quantize)}",
            f"file={self.name}.hlo.txt",
        ]
        return " ".join(toks)


def default_variants() -> list[Variant]:
    """Everything ``make artifacts`` builds."""
    out: list[Variant] = []
    # Full-size pblock detectors for every dataset dimensionality.
    for kind, r in PBLOCK_R.items():
        for d in DATASET_DIMS:
            out.append(Variant(kind=kind, d=d, r=r))
    # Small test variants: fast to execute in rust integration tests.
    for kind in PBLOCK_R:
        out.append(Variant(kind=kind, d=3, r=4))
        out.append(Variant(kind=kind, d=3, r=4, quantize=False))
    # Bypass (identity) RMs: d-wide passthrough, plus d=1 for score streams.
    for d in (1,) + DATASET_DIMS:
        out.append(Variant(kind="bypass", d=d))
    # Combo RMs (paper Table 2): 4 input score/label streams.
    for combo in ("avg", "max", "wavg", "or", "vote"):
        out.append(Variant(kind="combo", combo=combo))
    return out
