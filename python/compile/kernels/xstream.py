"""xStream front-end Pallas kernel (paper Algorithm 3, blocks ③+④).

Per sub-detector r the sample is densely projected ``[d] → [K]`` (the paper
UNROLLs the K-wide accumulation; here the R·K lanes become one contracted
einsum on the MXU), then *perbins* half-space-chain binning is applied per
CMS row — row i (1-based) halves the bin width: ``Δ_k / 2^i`` — and the K
bins are Jenkins-hashed (seed = 1-based row) into the CMS index space.

Output: CMS table indices [C,R,w] int32 for the L2 sliding-window scan.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

U32 = jnp.uint32


def _xstream_kernel(x_ref, proj_ref, shift_ref, width_ref, idx_ref,
                    *, w: int, mod: int):
    x = x_ref[...]                                    # [C,d]
    proj = proj_ref[...]                              # [R,d,K]
    r_dim, d, k = proj.shape
    # ③ Projection: contraction over d → [C,R,K] (MXU-shaped).
    z = jax.lax.dot_general(
        x, proj,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                 # [C,R,K]
    width = jnp.maximum(width_ref[...], 1e-12)        # [R,K]
    shift = shift_ref[...]                            # [R,w,K]
    for row in range(w):                              # HLS UNROLL over CMS rows
        scale = (2.0 ** (row + 1)) / width            # [R,K]
        b = jnp.floor((z - shift[None, :, row, :]) * scale[None])
        g = b.astype(jnp.int32).astype(U32)           # [C,R,K]
        h = jnp.full(g.shape[:-1], row + 1, dtype=U32)
        for i in range(k):                            # HLS PIPELINE: K static
            h = h + g[..., i]
            h = h + (h << U32(10))
            h = h ^ (h >> U32(6))
        h = h + (h << U32(3))
        h = h ^ (h >> U32(11))
        h = h + (h << U32(15))
        idx_ref[..., row] = (h % U32(mod)).astype(jnp.int32)


def xstream_frontend(x, proj, shift, width, *, w: int, mod: int):
    """x [C,d], proj [R,d,K], shift [R,w,K], width [R,K] → [C,R,w] i32."""
    c, _ = x.shape
    r = proj.shape[0]
    kernel = functools.partial(_xstream_kernel, w=w, mod=mod)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((c, r, w), jnp.int32),
        interpret=True,
    )(x, proj, shift, width)
