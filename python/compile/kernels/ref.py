"""Pure-jnp / numpy correctness oracles.

Two tiers:

1. ``*_frontend_ref`` — pure jnp implementations of the state-independent
   front-end (projection ③ + core hashing/binning ④) that the Pallas kernels
   accelerate. pytest asserts kernel == ref.
2. ``Streaming*Ref`` — slow, obviously-correct per-sample numpy
   implementations of the full detectors (①–⑦), used to validate the
   scan-based L2 model end to end.
"""

import numpy as np
import jax.numpy as jnp

from .jenkins import jenkins_mod

# ---------------------------------------------------------------------------
# Tier 1: batched front-end oracles (match the Pallas kernels exactly)
# ---------------------------------------------------------------------------


def loda_frontend_ref(x, prj, pmin, pmax, bins: int):
    """x [C,d] f32, prj [R,d], pmin/pmax [R] → bin index [C,R] int32."""
    z = x @ prj.T                                            # [C,R]
    span = jnp.maximum(pmax - pmin, 1e-12)
    idx = jnp.floor((z - pmin) / span * bins)
    return jnp.clip(idx, 0, bins - 1).astype(jnp.int32)


def rshash_frontend_ref(x, dmin, dmax, alpha, f, w: int, mod: int):
    """x [C,d], dmin/dmax [d], alpha [R,d], f [R] → CMS index [C,R,w] int32."""
    span = jnp.maximum(dmax - dmin, 1e-12)
    norm = (x - dmin) / span                                 # [C,d]
    prj = (norm[:, None, :] + alpha[None, :, :]) / f[None, :, None]  # [C,R,d]
    g = jnp.floor(prj).astype(jnp.int32)                     # integer grid key
    rows = []
    for row in range(w):
        rows.append(jenkins_mod(g, row + 1, mod))            # seed = 1-based row
    return jnp.stack(rows, axis=-1)                          # [C,R,w]


def xstream_frontend_ref(x, proj, shift, width, w: int, mod: int):
    """x [C,d], proj [R,d,K], shift [R,w,K], width [R,K] → [C,R,w] int32.

    Half-space-chain binning: row i (1-based) uses bin width ``width / 2^i``.
    """
    z = jnp.einsum("cd,rdk->crk", x, proj)                   # [C,R,K]
    rows = []
    for row in range(w):
        scale = (2.0 ** (row + 1)) / jnp.maximum(width, 1e-12)   # [R,K]
        b = jnp.floor((z - shift[:, row, :][None]) * scale[None])
        rows.append(jenkins_mod(b.astype(jnp.int32), row + 1, mod))
    return jnp.stack(rows, axis=-1)                          # [C,R,w]


# ---------------------------------------------------------------------------
# Tier 2: per-sample streaming references (numpy, slow, obviously correct)
# ---------------------------------------------------------------------------


def _jenkins_np(key_words, seed):
    h = np.uint32(seed)
    with np.errstate(over="ignore"):
        for kw in key_words:
            h = np.uint32(h + np.uint32(kw))
            h = np.uint32(h + np.uint32(h << np.uint32(10)))
            h = np.uint32(h ^ (h >> np.uint32(6)))
        h = np.uint32(h + np.uint32(h << np.uint32(3)))
        h = np.uint32(h ^ (h >> np.uint32(11)))
        h = np.uint32(h + np.uint32(h << np.uint32(15)))
    return h


def quantize_q16_16(v):
    """Q16.16 fixed point (ap_fixed<32,16> analogue)."""
    q = np.round(np.asarray(v, np.float64) * 65536.0).astype(np.int64)
    return np.float32(q.astype(np.float64) / 65536.0)


class _StreamBase:
    """Shared sliding-window machinery (⑤) — ring of inserted table indices."""

    def __init__(self, window):
        self.window = window
        self.pos = 0
        self.n = 0

    def _denom(self):
        return max(min(self.n, self.window), 1)


class StreamingLodaRef(_StreamBase):
    def __init__(self, prj, pmin, pmax, bins, window):
        super().__init__(window)
        self.prj = np.asarray(prj, np.float32)
        self.pmin = np.asarray(pmin, np.float32)
        self.pmax = np.asarray(pmax, np.float32)
        self.bins = bins
        self.R = self.prj.shape[0]
        self.hist = np.zeros((self.R, bins), np.int32)
        self.ring = np.zeros((self.R, window), np.int32)

    def update(self, x):
        x = np.asarray(x, np.float32)
        z = self.prj @ x                                     # [R]
        span = np.maximum(self.pmax - self.pmin, 1e-12)
        idx = np.floor((z - self.pmin) / span * self.bins)
        idx = np.clip(idx, 0, self.bins - 1).astype(np.int32)
        c = self.hist[np.arange(self.R), idx]
        score = np.mean(np.log2(self._denom()) - np.log2(np.maximum(c, 1)))
        if self.n >= self.window:
            old = self.ring[:, self.pos]
            self.hist[np.arange(self.R), old] -= 1
        self.hist[np.arange(self.R), idx] += 1
        self.ring[:, self.pos] = idx
        self.pos = (self.pos + 1) % self.window
        self.n += 1
        return np.float32(score)


class StreamingRsHashRef(_StreamBase):
    def __init__(self, dmin, dmax, alpha, f, w, mod, window):
        super().__init__(window)
        self.dmin = np.asarray(dmin, np.float32)
        self.dmax = np.asarray(dmax, np.float32)
        self.alpha = np.asarray(alpha, np.float32)
        self.f = np.asarray(f, np.float32)
        self.w, self.mod = w, mod
        self.R = self.alpha.shape[0]
        self.cms = np.zeros((self.R, w, mod), np.int32)
        self.ring = np.zeros((self.R, w, window), np.int32)

    def _indices(self, x):
        span = np.maximum(self.dmax - self.dmin, 1e-12)
        norm = (np.asarray(x, np.float32) - self.dmin) / span
        idx = np.zeros((self.R, self.w), np.int32)
        for r in range(self.R):
            g = np.floor((norm + self.alpha[r]) / self.f[r]).astype(np.int32)
            for row in range(self.w):
                idx[r, row] = _jenkins_np(g.astype(np.uint32), row + 1) % self.mod
        return idx

    def update(self, x):
        idx = self._indices(x)
        rr = np.arange(self.R)[:, None]
        ww = np.arange(self.w)[None, :]
        c = self.cms[rr, ww, idx]                            # [R,w]
        mins = c.min(axis=1)                                 # [R]
        score = np.mean(np.log2(self._denom()) - np.log2(1.0 + mins))
        if self.n >= self.window:
            old = self.ring[:, :, self.pos]
            np.add.at(self.cms, (rr, ww, old), -1)
        np.add.at(self.cms, (rr, ww, idx), 1)
        self.ring[:, :, self.pos] = idx
        self.pos = (self.pos + 1) % self.window
        self.n += 1
        return np.float32(score)


class StreamingXStreamRef(_StreamBase):
    def __init__(self, proj, shift, width, w, mod, window):
        super().__init__(window)
        self.proj = np.asarray(proj, np.float32)             # [R,d,K]
        self.shift = np.asarray(shift, np.float32)           # [R,w,K]
        self.width = np.asarray(width, np.float32)           # [R,K]
        self.w, self.mod = w, mod
        self.R = self.proj.shape[0]
        self.cms = np.zeros((self.R, w, mod), np.int32)
        self.ring = np.zeros((self.R, w, window), np.int32)

    def _indices(self, x):
        x = np.asarray(x, np.float32)
        idx = np.zeros((self.R, self.w), np.int32)
        for r in range(self.R):
            z = x @ self.proj[r]                             # [K]
            for row in range(self.w):
                scale = np.float32(2.0 ** (row + 1)) / np.maximum(
                    self.width[r], np.float32(1e-12)
                )
                b = np.floor((z - self.shift[r, row]) * scale).astype(np.int32)
                idx[r, row] = _jenkins_np(b.astype(np.uint32), row + 1) % self.mod
        return idx

    def update(self, x):
        idx = self._indices(x)
        rr = np.arange(self.R)[:, None]
        ww = np.arange(self.w)[None, :]
        c = self.cms[rr, ww, idx].astype(np.float64)         # [R,w]
        weighted = c * (2.0 ** (np.arange(self.w)[None, :] + 1))
        mins = weighted.min(axis=1)
        score = np.mean(np.log2(self._denom()) - np.log2(1.0 + mins))
        if self.n >= self.window:
            old = self.ring[:, :, self.pos]
            np.add.at(self.cms, (rr, ww, old), -1)
        np.add.at(self.cms, (rr, ww, idx), 1)
        self.ring[:, :, self.pos] = idx
        self.pos = (self.pos + 1) % self.window
        self.n += 1
        return np.float32(score)
