"""Jenkins one-at-a-time hash (paper Algorithm 4), vectorised for JAX.

The rust CPU baseline (``rust/src/detectors/jenkins.rs``) implements the
identical uint32 wrapping sequence; ``python/tests/test_jenkins.py`` checks
bit-exactness against shared test vectors.

All arithmetic is uint32 with natural wraparound (jnp uint32 == rust
``u32.wrapping_*``).
"""

import jax.numpy as jnp

U32 = jnp.uint32


def jenkins_hash(keys: jnp.ndarray, seed) -> jnp.ndarray:
    """Hash the trailing axis of ``keys``.

    keys : int32/uint32 array [..., L] — the key words (paper hashes the
           quantised projection values).
    seed : scalar or array broadcastable to keys[..., 0] — paper uses the
           CMS row index (1-based).
    Returns uint32 array [...] — raw hash (caller applies ``% MOD``).
    """
    k = keys.astype(U32)
    h = jnp.broadcast_to(jnp.asarray(seed, dtype=U32), k.shape[:-1])
    for i in range(k.shape[-1]):  # L is static → unrolled, matches HLS PIPELINE
        h = h + k[..., i]
        h = h + (h << U32(10))
        h = h ^ (h >> U32(6))
    h = h + (h << U32(3))
    h = h ^ (h >> U32(11))
    h = h + (h << U32(15))
    return h


def jenkins_mod(keys: jnp.ndarray, seed, mod: int) -> jnp.ndarray:
    """``jenkins_hash % mod`` as int32 (table index)."""
    return (jenkins_hash(keys, seed) % U32(mod)).astype(jnp.int32)
