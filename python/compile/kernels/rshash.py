"""RS-Hash front-end Pallas kernel (paper Algorithm 2, blocks ③+④).

Per sample: min-max normalise, shift by α_r, scale by 1/f_r, floor to the
integer grid, then Jenkins-hash the d grid cells once per CMS row
(seed = 1-based row). The FPGA unrolls the w CMS rows (HLS ``UNROLL``) and
pipelines the per-dimension loop (``PIPELINE II=1``); here both become array
axes evaluated in one kernel invocation — [C,R] lanes per row on the VPU,
with the d-step Jenkins recurrence unrolled (d is static).

Output: CMS table indices [C,R,w] int32 for the L2 sliding-window scan.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

U32 = jnp.uint32


def _rshash_kernel(x_ref, dmin_ref, dmax_ref, alpha_ref, f_ref, idx_ref,
                   *, w: int, mod: int):
    x = x_ref[...]                                    # [C,d]
    dmin = dmin_ref[...]                              # [d]
    span = jnp.maximum(dmax_ref[...] - dmin, 1e-12)
    norm = (x - dmin[None, :]) / span[None, :]        # [C,d]
    alpha = alpha_ref[...]                            # [R,d]
    f = f_ref[...]                                    # [R]
    prj = (norm[:, None, :] + alpha[None, :, :]) / f[None, :, None]  # [C,R,d]
    g = jnp.floor(prj).astype(jnp.int32).astype(U32)  # integer grid key
    d = g.shape[-1]
    for row in range(w):                              # HLS UNROLL over CMS rows
        h = jnp.full(g.shape[:-1], row + 1, dtype=U32)
        for i in range(d):                            # HLS PIPELINE: d static
            h = h + g[..., i]
            h = h + (h << U32(10))
            h = h ^ (h >> U32(6))
        h = h + (h << U32(3))
        h = h ^ (h >> U32(11))
        h = h + (h << U32(15))
        idx_ref[..., row] = (h % U32(mod)).astype(jnp.int32)


def rshash_frontend(x, dmin, dmax, alpha, f, *, w: int, mod: int):
    """x [C,d], dmin/dmax [d], alpha [R,d], f [R] → CMS indices [C,R,w] i32."""
    c, _ = x.shape
    r, _ = alpha.shape
    kernel = functools.partial(_rshash_kernel, w=w, mod=mod)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((c, r, w), jnp.int32),
        interpret=True,
    )(x, dmin, dmax, alpha, f)
