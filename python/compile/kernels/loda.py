"""Loda front-end Pallas kernel (paper Algorithm 1, blocks ③+④a).

The FPGA design runs R sub-detector projection pipelines spatially in
parallel (HLS ``DATAFLOW`` + ``PIPELINE II=1``). On the TPU-shaped Pallas
model this becomes ONE matmul ``[C,d] × [d,R]`` feeding the MXU, followed by
element-wise binning on the VPU — projection is state-independent, so the
whole chunk is computed up front and only the sliding-window update (⑤)
remains sequential (handled in the L2 scan).

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO so the artifact runs on any
backend. Real-TPU VMEM/MXU estimates live in DESIGN.md / EXPERIMENTS.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _loda_kernel(x_ref, prj_ref, pmin_ref, pmax_ref, idx_ref, *, bins: int):
    # ③ Projection: one MXU matmul replaces R parallel dot-product pipelines.
    x = x_ref[...]                      # [C,d] f32 (VMEM block)
    prj = prj_ref[...]                  # [R,d] f32
    z = jnp.dot(x, prj.T, preferred_element_type=jnp.float32)   # [C,R]
    # ④a Histogram binning (the gather/update against state happens in L2).
    pmin = pmin_ref[...]                # [R]
    span = jnp.maximum(pmax_ref[...] - pmin, 1e-12)
    idx = jnp.floor((z - pmin[None, :]) / span[None, :] * bins)
    idx_ref[...] = jnp.clip(idx, 0, bins - 1).astype(jnp.int32)


def loda_frontend(x, prj, pmin, pmax, *, bins: int):
    """x [C,d], prj [R,d], pmin/pmax [R] → histogram bin indices [C,R] i32."""
    c, _ = x.shape
    r, _ = prj.shape
    kernel = functools.partial(_loda_kernel, bins=bins)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((c, r), jnp.int32),
        interpret=True,
    )(x, prj, pmin, pmax)
