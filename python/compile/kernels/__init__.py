# L1: Pallas kernels for the detector front-ends (projection + hashing),
# plus the pure-jnp/numpy oracles in ref.py.
from .jenkins import jenkins_hash, jenkins_mod
from .loda import loda_frontend
from .rshash import rshash_frontend
from .xstream import xstream_frontend

__all__ = [
    "jenkins_hash",
    "jenkins_mod",
    "loda_frontend",
    "rshash_frontend",
    "xstream_frontend",
]
