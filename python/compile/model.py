"""L2: streaming detector models (paper Algorithms 1–3, blocks ①–⑦).

Each detector is a *chunk step*: it consumes a chunk of C samples plus the
sliding-window state, and returns per-sample ensemble anomaly scores plus the
updated state. The state-independent front-end (projection ③ + hashing ④) is
computed for the whole chunk by one Pallas kernel call (L1); only the
sliding-window update (⑤) is sequential, expressed as a ``lax.scan``.

The rust coordinator executes these as AOT-compiled HLO with state threaded
through successive invocations — streaming semantics are exact (sample i's
score never sees sample j ≥ i).

Padding: the final chunk of a stream is padded; ``mask`` marks valid samples.
Masked samples produce score 0 and leave the state untouched.

Scores: ``log2(min(n,W)) − log2(count-term)`` — a monotone transform of the
paper's ``−log2(c/W)`` family (Table 1), so ROC-AUC is identical; higher
means more anomalous. With ``quantize=True`` scores are rounded to Q16.16,
the ap_fixed<32,16> analogue (paper §4.4).
"""

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax import lax

from .kernels import loda_frontend, rshash_frontend, xstream_frontend
from .kernels import ref as kref


@dataclasses.dataclass(frozen=True)
class DetectorConfig:
    """Static configuration baked into one artifact (see manifest.Variant)."""

    d: int
    r: int
    chunk: int = 256
    window: int = 128
    bins: int = 20
    w: int = 2
    mod: int = 128
    k: int = 20
    quantize: bool = True


def _q16(scores):
    """Q16.16 fixed-point rounding (ap_fixed<32,16> analogue)."""
    return jnp.round(scores * 65536.0).astype(jnp.int32).astype(jnp.float32) / 65536.0


def _finish(cfg, scores):
    return _q16(scores) if cfg.quantize else scores


# ---------------------------------------------------------------------------
# Loda (Algorithm 1): histogram core, 1×W window
# ---------------------------------------------------------------------------


def loda_init_state(cfg: DetectorConfig):
    return (
        jnp.zeros((cfg.r, cfg.bins), jnp.int32),    # hist
        jnp.zeros((cfg.r, cfg.window), jnp.int32),  # ring of inserted bins
        jnp.zeros((1,), jnp.int32),                 # pos
        jnp.zeros((1,), jnp.int32),                 # n (samples seen)
    )


#: lax.scan unroll factor for the sliding-window loops.
#: §Perf ablation (EXPERIMENTS.md): on jaxlib 0.8.2's XLA, unroll=16 wins
#: 2.8× for Loda (6.9 → 2.5 µs/sample); on the *deployed* runtime
#: (xla_extension 0.5.1) it is neutral-to-slightly-worse while costing 10×
#: in compile time, so the shipped default is 1. Override with
#: FSEAD_SCAN_UNROLL=16 when targeting a modern PJRT runtime.
import os as _os

SCAN_UNROLL = int(_os.environ.get("FSEAD_SCAN_UNROLL", "1"))


def loda_chunk(cfg: DetectorConfig, x, mask, prj, pmin, pmax,
               hist, ring, pos, n, *, use_ref: bool = False):
    """x [C,d] f32, mask [C] f32 → (scores [C], hist', ring', pos', n')."""
    frontend = kref.loda_frontend_ref if use_ref else loda_frontend
    idx = frontend(x, prj, pmin, pmax, bins=cfg.bins)        # [C,R] i32
    rr = jnp.arange(cfg.r)
    rr2 = jnp.concatenate([rr, rr])
    ones_r = jnp.ones(cfg.r, jnp.int32)
    win = jnp.int32(cfg.window)

    def step(carry, inp):
        hist, ring, pos, n = carry
        idx_c, m = inp
        valid = m > 0.5
        p = pos[0]
        nn = n[0]
        # ⑥ Score (read-before-insert, per Algorithm 1 line 15/19)
        denom = jnp.maximum(jnp.minimum(nn, win), 1).astype(jnp.float32)
        c = hist[rr, idx_c].astype(jnp.float32)
        score = jnp.mean(jnp.log2(denom) - jnp.log2(jnp.maximum(c, 1.0)))
        # ⑤ Sliding-window update: insert new + evict oldest as ONE fused
        #   scatter-add (§Perf: halves the scatter count; adds commute).
        evict = (nn >= win) & valid
        old = ring[:, p]
        upd = jnp.concatenate([
            jnp.where(valid, 1, 0) * ones_r,
            jnp.where(evict, -1, 0) * ones_r,
        ])
        hist = hist.at[rr2, jnp.concatenate([idx_c, old])].add(upd)
        ring = ring.at[:, p].set(jnp.where(valid, idx_c, old))
        pos = jnp.where(valid, (pos + 1) % win, pos)
        n = jnp.where(valid, n + 1, n)
        return (hist, ring, pos, n), jnp.where(valid, score, 0.0)

    (hist, ring, pos, n), scores = lax.scan(
        step, (hist, ring, pos, n), (idx, mask), unroll=SCAN_UNROLL
    )
    return (_finish(cfg, scores), hist, ring, pos, n)


# ---------------------------------------------------------------------------
# RS-Hash (Algorithm 2) and xStream (Algorithm 3): CMS core, w×W window
# ---------------------------------------------------------------------------


def cms_init_state(cfg: DetectorConfig):
    return (
        jnp.zeros((cfg.r, cfg.w, cfg.mod), jnp.int32),     # cms
        jnp.zeros((cfg.r, cfg.w, cfg.window), jnp.int32),  # ring of indices
        jnp.zeros((1,), jnp.int32),                        # pos
        jnp.zeros((1,), jnp.int32),                        # n
    )


def _cms_scan(cfg: DetectorConfig, idx, mask, cms, ring, pos, n, row_weights):
    """Shared CMS sliding-window scan. idx [C,R,w]; row_weights [w] scales the
    per-row counts before the min (1 for RS-Hash, 2^row for xStream)."""
    rr = jnp.arange(cfg.r)[:, None]
    ww = jnp.arange(cfg.w)[None, :]
    win = jnp.int32(cfg.window)
    rw = row_weights[None, :]                                # [1,w]

    def step(carry, inp):
        cms, ring, pos, n = carry
        idx_c, m = inp                                       # [R,w], scalar
        valid = m > 0.5
        p = pos[0]
        nn = n[0]
        denom = jnp.maximum(jnp.minimum(nn, win), 1).astype(jnp.float32)
        c = cms[rr, ww, idx_c].astype(jnp.float32)           # [R,w]
        mins = jnp.min(c * rw, axis=1)                       # [R]
        score = jnp.mean(jnp.log2(denom) - jnp.log2(1.0 + mins))
        evict = (nn >= win) & valid
        old = ring[:, :, p]
        cms = cms.at[rr, ww, old].add(jnp.where(evict, -1, 0))
        cms = cms.at[rr, ww, idx_c].add(jnp.where(valid, 1, 0))
        ring = ring.at[:, :, p].set(jnp.where(valid, idx_c, old))
        pos = jnp.where(valid, (pos + 1) % win, pos)
        n = jnp.where(valid, n + 1, n)
        return (cms, ring, pos, n), jnp.where(valid, score, 0.0)

    (cms, ring, pos, n), scores = lax.scan(
        step, (cms, ring, pos, n), (idx, mask), unroll=SCAN_UNROLL
    )
    return scores, cms, ring, pos, n


def rshash_chunk(cfg: DetectorConfig, x, mask, dmin, dmax, alpha, f,
                 cms, ring, pos, n, *, use_ref: bool = False):
    """x [C,d] → (scores [C], cms', ring', pos', n')."""
    frontend = kref.rshash_frontend_ref if use_ref else rshash_frontend
    idx = frontend(x, dmin, dmax, alpha, f, w=cfg.w, mod=cfg.mod)
    weights = jnp.ones((cfg.w,), jnp.float32)
    scores, cms, ring, pos, n = _cms_scan(cfg, idx, mask, cms, ring, pos, n, weights)
    return (_finish(cfg, scores), cms, ring, pos, n)


def xstream_chunk(cfg: DetectorConfig, x, mask, proj, shift, width,
                  cms, ring, pos, n, *, use_ref: bool = False):
    """x [C,d] → (scores [C], cms', ring', pos', n')."""
    frontend = kref.xstream_frontend_ref if use_ref else xstream_frontend
    idx = frontend(x, proj, shift, width, w=cfg.w, mod=cfg.mod)
    weights = 2.0 ** (jnp.arange(cfg.w, dtype=jnp.float32) + 1.0)
    scores, cms, ring, pos, n = _cms_scan(cfg, idx, mask, cms, ring, pos, n, weights)
    return (_finish(cfg, scores), cms, ring, pos, n)


# ---------------------------------------------------------------------------
# Bypass + Combo RMs (paper Table 2, Figure 20)
# ---------------------------------------------------------------------------


def bypass(x):
    """Identity RM — the paper's default/bypass pblock logic."""
    return (x,)


def combo_avg(scores, active):
    """Averaging (GG_A). scores [C,4], active [4] ∈ {0,1}."""
    tot = jnp.maximum(jnp.sum(active), 1.0)
    return (jnp.sum(scores * active[None, :], axis=1) / tot,)


def combo_max(scores, active):
    """Maximization (GG_M)."""
    neg = jnp.float32(-3.0e38)
    masked = jnp.where(active[None, :] > 0.5, scores, neg)
    return (jnp.max(masked, axis=1),)


def combo_wavg(scores, active, weights):
    """Weighted average (GG_WA); weights renormalised over active inputs."""
    aw = active * weights
    tot = jnp.maximum(jnp.sum(aw), 1e-12)
    return (jnp.sum(scores * aw[None, :], axis=1) / tot,)


def combo_or(labels, active):
    """OR combination of binary labels: anomaly if any active input is 1."""
    return (jnp.max(labels * active[None, :], axis=1),)


def combo_vote(labels, active):
    """Majority voting; ties resolve to anomaly (consistent with OR's
    don't-miss-an-anomaly bias, paper §4.2)."""
    votes = jnp.sum(labels * active[None, :], axis=1)
    quorum = jnp.sum(active)
    return ((2.0 * votes >= quorum).astype(jnp.float32),)


# ---------------------------------------------------------------------------
# Variant → (callable, example args) for AOT lowering
# ---------------------------------------------------------------------------


def build_fn_and_specs(variant):
    """Return (fn, example_args) for ``jax.jit(fn).lower(*example_args)``."""
    f32, i32 = jnp.float32, jnp.int32
    S = jax.ShapeDtypeStruct
    if variant.kind == "bypass":
        return bypass, (S((variant.chunk, variant.d), f32),)
    if variant.kind == "combo":
        sc = S((variant.chunk, 4), f32)
        a = S((4,), f32)
        fns = {
            "avg": (combo_avg, (sc, a)),
            "max": (combo_max, (sc, a)),
            "wavg": (combo_wavg, (sc, a, S((4,), f32))),
            "or": (combo_or, (sc, a)),
            "vote": (combo_vote, (sc, a)),
        }
        return fns[variant.combo]

    cfg = DetectorConfig(
        d=variant.d, r=variant.r, chunk=variant.chunk, window=variant.window,
        bins=variant.bins, w=variant.w, mod=variant.mod, k=variant.k,
        quantize=variant.quantize,
    )
    x = S((cfg.chunk, cfg.d), f32)
    mask = S((cfg.chunk,), f32)
    pos = S((1,), i32)
    n = S((1,), i32)
    if variant.kind == "loda":
        fn = functools.partial(loda_chunk, cfg)
        args = (
            x, mask,
            S((cfg.r, cfg.d), f32),               # prj
            S((cfg.r,), f32), S((cfg.r,), f32),   # pmin, pmax
            S((cfg.r, cfg.bins), i32),            # hist
            S((cfg.r, cfg.window), i32),          # ring
            pos, n,
        )
        return fn, args
    if variant.kind == "rshash":
        fn = functools.partial(rshash_chunk, cfg)
        args = (
            x, mask,
            S((cfg.d,), f32), S((cfg.d,), f32),   # dmin, dmax
            S((cfg.r, cfg.d), f32),               # alpha
            S((cfg.r,), f32),                     # f
            S((cfg.r, cfg.w, cfg.mod), i32),      # cms
            S((cfg.r, cfg.w, cfg.window), i32),   # ring
            pos, n,
        )
        return fn, args
    if variant.kind == "xstream":
        fn = functools.partial(xstream_chunk, cfg)
        args = (
            x, mask,
            S((cfg.r, cfg.d, cfg.k), f32),        # proj
            S((cfg.r, cfg.w, cfg.k), f32),        # shift
            S((cfg.r, cfg.k), f32),               # width
            S((cfg.r, cfg.w, cfg.mod), i32),      # cms
            S((cfg.r, cfg.w, cfg.window), i32),   # ring
            pos, n,
        )
        return fn, args
    raise ValueError(f"unknown variant kind: {variant.kind}")
