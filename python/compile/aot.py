"""AOT compile path: lower every manifest variant to HLO *text*.

HLO text (not ``.serialize()``) is the interchange format: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids which the rust side's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Run once via ``make artifacts`` (no-op when inputs are unchanged); the rust
binary is self-contained afterwards — python never sits on the request path.

Usage: ``cd python && python -m compile.aot --out-dir ../artifacts``
"""

import argparse
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from .manifest import default_variants
from .model import build_fn_and_specs


def to_hlo_text(lowered) -> str:
    """stablehlo MLIR → XlaComputation → HLO text (returns a tuple root)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(variant) -> str:
    fn, example_args = build_fn_and_specs(variant)
    lowered = jax.jit(fn).lower(*example_args)
    return to_hlo_text(lowered)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default="",
                    help="comma-separated variant names to (re)build")
    args = ap.parse_args(argv)

    os.makedirs(args.out_dir, exist_ok=True)
    only = {s for s in args.only.split(",") if s}
    variants = default_variants()
    lines = []
    for v in variants:
        path = os.path.join(args.out_dir, f"{v.name}.hlo.txt")
        lines.append(v.manifest_line())
        if only and v.name not in only:
            continue
        text = lower_variant(v)
        with open(path, "w") as fh:
            fh.write(text)
        print(f"[aot] {v.name}: {len(text)} chars -> {path}", flush=True)
    manifest_path = os.path.join(args.out_dir, "manifest.txt")
    with open(manifest_path, "w") as fh:
        fh.write("\n".join(lines) + "\n")
    print(f"[aot] wrote manifest with {len(lines)} variants -> {manifest_path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
