"""Generate the checked-in golden score fixtures for tests/golden_vectors.rs.

Bit-level port of the rust CPU detectors (rust/src/detectors/) — same
xoshiro256** / SplitMix64 parameter streams, same Jenkins hashing, and the
same f32 operation order in the score path. f32 arithmetic is emulated by
performing each elementary operation in f64 and rounding to f32
(struct-pack), which is exact for +, -, *, / when both operands are f32
(f64 carries more than 2x24+2 significand bits, so no double-rounding).
log2 is evaluated in f64 and rounded; its inputs in the score path are
small integer-valued floats, so the result matches the platform log2f to
well under the 1e-6 fixture tolerance.

Usage:  python3 python/tools/gen_golden_vectors.py [out_dir]

The configuration here must mirror tests/golden_vectors.rs exactly:
stream = 64 samples of d=3 unit gaussians from Prng(20240601), warm-up =
first 16 samples, window=16, bins=8, w=2, modulus=32, k=4, r=4, seed=7.
"""

import math
import os
import struct
import sys

M64 = (1 << 64) - 1
M32 = 0xFFFFFFFF


def f32(x):
    """Round a python float to the nearest IEEE binary32 value."""
    return struct.unpack("<f", struct.pack("<f", x))[0]


def log2_f32(x):
    return f32(math.log2(x))


# ---------------------------------------------------------------------------
# PRNG substrate (rust/src/detectors/prng.rs)
# ---------------------------------------------------------------------------


class SplitMix64:
    def __init__(self, seed):
        self.s = seed & M64

    def next_u64(self):
        self.s = (self.s + 0x9E3779B97F4A7C15) & M64
        z = self.s
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & M64
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & M64
        return (z ^ (z >> 31)) & M64


def rotl(x, k):
    return ((x << k) | (x >> (64 - k))) & M64


class Prng:
    def __init__(self, seed):
        sm = SplitMix64(seed)
        self.s = [sm.next_u64() for _ in range(4)]
        self.spare = None

    def child(self, stream):
        return Prng(self.s[0] ^ ((stream * 0xA24BAED4963EE407) & M64))

    def next_u64(self):
        s = self.s
        result = (rotl((s[1] * 5) & M64, 7) * 9) & M64
        t = (s[1] << 17) & M64
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = rotl(s[3], 45)
        return result

    def uniform(self):
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))

    def uniform_in(self, lo, hi):
        return lo + (hi - lo) * self.uniform()

    def below(self, n):
        return int(self.uniform() * n) % n

    def gaussian(self):
        if self.spare is not None:
            z, self.spare = self.spare, None
            return z
        while True:
            u1 = self.uniform()
            if u1 > 1e-300:
                break
        u2 = self.uniform()
        r = math.sqrt(-2.0 * math.log(u1))
        theta = 2.0 * math.pi * u2
        self.spare = r * math.sin(theta)
        return r * math.cos(theta)

    def choose_k(self, n, k):
        idx = list(range(n))
        k = min(k, n)
        for i in range(k):
            j = i + self.below(n - i)
            idx[i], idx[j] = idx[j], idx[i]
        return idx[:k]


# ---------------------------------------------------------------------------
# Jenkins one-at-a-time (rust/src/detectors/jenkins.rs)
# ---------------------------------------------------------------------------


def jenkins_hash(key_u32, seed):
    h = seed & M32
    for k in key_u32:
        h = (h + (k & M32)) & M32
        h = (h + ((h << 10) & M32)) & M32
        h ^= h >> 6
    h = (h + ((h << 3) & M32)) & M32
    h ^= h >> 11
    h = (h + ((h << 15) & M32)) & M32
    return h


def jenkins_mod_i32(key_i32, seed, modulus):
    return jenkins_hash([k & M32 for k in key_i32], seed) % modulus


# Shared golden vectors from rust/src/detectors/jenkins.rs — the port must
# reproduce them exactly before any fixture is written.
JENKINS_GOLDEN = [
    ([0], 0, 0x00000000),
    ([1, 2, 3], 1, 0x54EE7BFA),
    ([0xFFFFFFFF], 7, 0x6DC75B8D),
    ([42, 0, 42, 0xDEADBEEF], 2, 0x1FF9CDF1),
    ([5, 4, 3, 2, 1, 0], 123456, 0x1C57948C),
]


# ---------------------------------------------------------------------------
# Sliding-window count tables (rust/src/detectors/window.rs)
# ---------------------------------------------------------------------------


class SlidingCounts:
    def __init__(self, rows, width, window):
        self.rows, self.width, self.window = rows, width, window
        self.counts = [[0] * width for _ in range(rows)]
        self.ring = [[0] * window for _ in range(rows)]
        self.pos = 0
        self.n = 0

    def denom(self):
        return f32(max(min(self.n, self.window), 1))

    def get(self, row, idx):
        return self.counts[row][idx]

    def insert(self, idxs):
        evict = self.n >= self.window
        for row, idx in enumerate(idxs):
            if evict:
                old = self.ring[row][self.pos]
                self.counts[row][old] -= 1
            self.counts[row][idx] += 1
            self.ring[row][self.pos] = idx
        self.pos += 1
        if self.pos == self.window:
            self.pos = 0
        self.n += 1


# ---------------------------------------------------------------------------
# Parameter generation (rust/src/detectors/params.rs)
# ---------------------------------------------------------------------------


def loda_params(seed, r, d, warmup):
    root = Prng(seed)
    nnz = int(math.ceil(math.sqrt(d)))
    prj = [0.0] * (r * d)
    for ri in range(r):
        p = root.child(ri)
        for dim in p.choose_k(d, nnz):
            prj[ri * d + dim] = f32(p.gaussian())
    n = len(warmup) // d if d else 0
    pmin = [math.inf] * r
    pmax = [-math.inf] * r
    for s in range(n):
        x = warmup[s * d : (s + 1) * d]
        for ri in range(r):
            z = f32(0.0)
            for di in range(d):
                z = f32(z + f32(prj[ri * d + di] * x[di]))
            pmin[ri] = min(pmin[ri], z)
            pmax[ri] = max(pmax[ri], z)
    for ri in range(r):
        if n == 0 or pmin[ri] >= pmax[ri]:
            norm = f32(0.0)
            for di in range(d):
                w = prj[ri * d + di]
                norm = f32(norm + f32(w * w))
            s = f32(3.0 * max(f32(math.sqrt(norm)), f32(1e-6)))
            pmin[ri], pmax[ri] = f32(-s), s
        else:
            margin = f32(f32(0.1) * max(f32(pmax[ri] - pmin[ri]), f32(1e-6)))
            pmin[ri] = f32(pmin[ri] - margin)
            pmax[ri] = f32(pmax[ri] + margin)
    return prj, pmin, pmax


def dim_range(d, warmup):
    n = len(warmup) // d if d else 0
    dmin = [math.inf] * d
    dmax = [-math.inf] * d
    for s in range(n):
        for dim in range(d):
            v = warmup[s * d + dim]
            dmin[dim] = min(dmin[dim], v)
            dmax[dim] = max(dmax[dim], v)
    for dim in range(d):
        if n == 0 or dmin[dim] > dmax[dim]:
            dmin[dim], dmax[dim] = 0.0, 1.0
    return dmin, dmax


def rshash_params(seed, r, d, window, warmup):
    root = Prng(seed)
    dmin, dmax = dim_range(d, warmup)
    srt = 1.0 / math.sqrt(window)
    flo, fhi = min(srt, 0.49), max(1.0 - srt, 0.51)
    alpha = [0.0] * (r * d)
    f = [0.0] * r
    for ri in range(r):
        p = root.child(ri)
        fr = f32(p.uniform_in(flo, fhi))
        f[ri] = fr
        for dim in range(d):
            alpha[ri * d + dim] = f32(f32(p.uniform()) * fr)
    return dmin, dmax, alpha, f


def xstream_params(seed, r, d, k, w, warmup):
    root = Prng(seed)
    scale = 1.0 / math.sqrt(k)
    proj = [0.0] * (r * d * k)
    shift = [0.0] * (r * w * k)
    width = [0.0] * (r * k)
    n = len(warmup) // d if d else 0
    for ri in range(r):
        p = root.child(ri)
        for di in range(d):
            for ki in range(k):
                proj[(ri * d + di) * k + ki] = f32(p.gaussian() * scale)
        for ki in range(k):
            lo, hi = math.inf, -math.inf
            for s in range(n):
                x = warmup[s * d : (s + 1) * d]
                z = f32(0.0)
                for di in range(d):
                    z = f32(z + f32(x[di] * proj[(ri * d + di) * k + ki]))
                lo = min(lo, z)
                hi = max(hi, z)
            wdt = f32(1.0) if (n == 0 or hi <= lo) else max(f32(hi - lo), f32(1e-3))
            width[ri * k + ki] = wdt
            for wi in range(w):
                shift[(ri * w + wi) * k + ki] = f32(f32(p.uniform()) * wdt)
    return proj, shift, width


# ---------------------------------------------------------------------------
# Detectors — exact f32 ports of the rust `update` loops
# ---------------------------------------------------------------------------


class Loda:
    def __init__(self, seed, r, d, bins, window, warmup):
        self.r, self.d, self.bins = r, d, bins
        self.prj, self.pmin, self.pmax = loda_params(seed, r, d, warmup)
        self.span = [max(f32(self.pmax[ri] - self.pmin[ri]), f32(1e-12)) for ri in range(r)]
        self.counts = SlidingCounts(r, bins, window)

    def update(self, x):
        denom = self.counts.denom()
        dl = log2_f32(denom)
        total = f32(0.0)
        idxs = []
        for ri in range(self.r):
            z = f32(0.0)
            for di in range(self.d):
                z = f32(z + f32(self.prj[ri * self.d + di] * x[di]))
            raw = f32(f32(f32(z - self.pmin[ri]) / self.span[ri]) * f32(self.bins))
            idx = int(math.floor(raw))
            idx = max(0, min(idx, self.bins - 1))
            idxs.append(idx)
            c = f32(self.counts.get(ri, idx))
            total = f32(total + f32(dl - log2_f32(max(c, f32(1.0)))))
        self.counts.insert(idxs)
        return f32(total / f32(self.r))


class RsHash:
    def __init__(self, seed, r, d, w, modulus, window, warmup):
        self.r, self.d, self.w, self.mod = r, d, w, modulus
        self.dmin, self.dmax, self.alpha, self.f = rshash_params(seed, r, d, window, warmup)
        self.span = [max(f32(self.dmax[di] - self.dmin[di]), f32(1e-12)) for di in range(d)]
        self.counts = SlidingCounts(r * w, modulus, window)

    def update(self, x):
        denom = self.counts.denom()
        dl = log2_f32(denom)
        total = f32(0.0)
        idxs = [0] * (self.r * self.w)
        for ri in range(self.r):
            fr = self.f[ri]
            key = []
            for di in range(self.d):
                norm = f32(f32(x[di] - self.dmin[di]) / self.span[di])
                prj = f32(f32(norm + self.alpha[ri * self.d + di]) / fr)
                key.append(int(math.floor(prj)))
            min_c = None
            for row in range(self.w):
                idx = jenkins_mod_i32(key, row + 1, self.mod)
                idxs[ri * self.w + row] = idx
                c = self.counts.get(ri * self.w + row, idx)
                min_c = c if min_c is None else min(min_c, c)
            total = f32(total + f32(dl - log2_f32(f32(1.0 + f32(min_c)))))
        self.counts.insert(idxs)
        return f32(total / f32(self.r))


class XStream:
    def __init__(self, seed, r, d, k, w, modulus, window, warmup):
        self.r, self.d, self.k, self.w, self.mod = r, d, k, w, modulus
        self.proj, self.shift, self.width = xstream_params(seed, r, d, k, w, warmup)
        self.scale = [0.0] * (r * w * k)
        for ri in range(r):
            for row in range(w):
                pow_ = f32(1 << (row + 1))
                for ki in range(k):
                    wd = max(self.width[ri * k + ki], f32(1e-12))
                    self.scale[(ri * w + row) * k + ki] = f32(pow_ / wd)
        self.counts = SlidingCounts(r * w, modulus, window)

    def update(self, x):
        denom = self.counts.denom()
        dl = log2_f32(denom)
        total = f32(0.0)
        idxs = [0] * (self.r * self.w)
        for ri in range(self.r):
            z = []
            for ki in range(self.k):
                acc = f32(0.0)
                for di in range(self.d):
                    acc = f32(acc + f32(x[di] * self.proj[(ri * self.d + di) * self.k + ki]))
                z.append(acc)
            min_weighted = math.inf
            for row in range(self.w):
                pow_ = f32(1 << (row + 1))
                base = (ri * self.w + row) * self.k
                key = []
                for ki in range(self.k):
                    b = f32(f32(z[ki] - self.shift[base + ki]) * self.scale[base + ki])
                    key.append(int(math.floor(b)))
                idx = jenkins_mod_i32(key, row + 1, self.mod)
                idxs[ri * self.w + row] = idx
                c = f32(self.counts.get(ri * self.w + row, idx))
                min_weighted = min(min_weighted, f32(c * pow_))
            total = f32(total + f32(dl - log2_f32(f32(1.0 + min_weighted))))
        self.counts.insert(idxs)
        return f32(total / f32(self.r))


# ---------------------------------------------------------------------------
# Independent f64 cross-checks (ported from python/compile/kernels/ref.py
# Streaming*Ref — structurally independent of the f32 ports above)
# ---------------------------------------------------------------------------


def loda_ref_scores(det, data, d):
    counts = SlidingCounts(det.r, det.bins, det.counts.window)
    out = []
    for s in range(len(data) // d):
        x = data[s * d : (s + 1) * d]
        denom = max(min(counts.n, counts.window), 1)
        acc = 0.0
        idxs = []
        for ri in range(det.r):
            z = sum(det.prj[ri * d + di] * x[di] for di in range(d))
            span = max(det.pmax[ri] - det.pmin[ri], 1e-12)
            idx = int(math.floor((z - det.pmin[ri]) / span * det.bins))
            idx = max(0, min(idx, det.bins - 1))
            idxs.append(idx)
            acc += math.log2(denom) - math.log2(max(counts.get(ri, idx), 1))
        counts.insert(idxs)
        out.append(acc / det.r)
    return out


def rshash_ref_scores(det, data, d):
    counts = SlidingCounts(det.r * det.w, det.mod, det.counts.window)
    out = []
    for s in range(len(data) // d):
        x = data[s * d : (s + 1) * d]
        denom = max(min(counts.n, counts.window), 1)
        acc = 0.0
        idxs = [0] * (det.r * det.w)
        for ri in range(det.r):
            key = []
            for di in range(d):
                span = max(det.dmax[di] - det.dmin[di], 1e-12)
                norm = (x[di] - det.dmin[di]) / span
                key.append(int(math.floor((norm + det.alpha[ri * d + di]) / det.f[ri])))
            cs = []
            for row in range(det.w):
                idx = jenkins_mod_i32(key, row + 1, det.mod)
                idxs[ri * det.w + row] = idx
                cs.append(counts.get(ri * det.w + row, idx))
            acc += math.log2(denom) - math.log2(1.0 + min(cs))
        counts.insert(idxs)
        out.append(acc / det.r)
    return out


def xstream_ref_scores(det, data, d):
    counts = SlidingCounts(det.r * det.w, det.mod, det.counts.window)
    out = []
    for s in range(len(data) // d):
        x = data[s * d : (s + 1) * d]
        denom = max(min(counts.n, counts.window), 1)
        acc = 0.0
        idxs = [0] * (det.r * det.w)
        for ri in range(det.r):
            z = [
                sum(x[di] * det.proj[(ri * d + di) * det.k + ki] for di in range(d))
                for ki in range(det.k)
            ]
            weighted = []
            for row in range(det.w):
                base = (ri * det.w + row) * det.k
                key = []
                for ki in range(det.k):
                    scale = (2.0 ** (row + 1)) / max(det.width[ri * det.k + ki], 1e-12)
                    key.append(int(math.floor((z[ki] - det.shift[base + ki]) * scale)))
                idx = jenkins_mod_i32(key, row + 1, det.mod)
                idxs[ri * det.w + row] = idx
                weighted.append(counts.get(ri * det.w + row, idx) * (2.0 ** (row + 1)))
            acc += math.log2(denom) - math.log2(1.0 + min(weighted))
        counts.insert(idxs)
        out.append(acc / det.r)
    return out


# ---------------------------------------------------------------------------
# Fixture generation (mirrors tests/golden_vectors.rs)
# ---------------------------------------------------------------------------

STREAM_SEED = 20240601
N, D = 64, 3
WARMUP_SAMPLES = 16
WINDOW, BINS, W, MODULUS, K = 16, 8, 2, 32, 4
R, DET_SEED = 4, 7


def main():
    out_dir = sys.argv[1] if len(sys.argv) > 1 else "tests/fixtures"
    for key, seed, want in JENKINS_GOLDEN:
        got = jenkins_hash(key, seed)
        assert got == want, f"jenkins port broken: key={key} got={got:#x} want={want:#x}"

    p = Prng(STREAM_SEED)
    data = [f32(p.gaussian()) for _ in range(N * D)]
    warmup = data[: WARMUP_SAMPLES * D]

    detectors = {
        "loda": Loda(DET_SEED, R, D, BINS, WINDOW, warmup),
        "rshash": RsHash(DET_SEED, R, D, W, MODULUS, WINDOW, warmup),
        "xstream": XStream(DET_SEED, R, D, K, W, MODULUS, WINDOW, warmup),
    }
    refs = {"loda": loda_ref_scores, "rshash": rshash_ref_scores, "xstream": xstream_ref_scores}

    os.makedirs(out_dir, exist_ok=True)
    for name, det in detectors.items():
        scores = [det.update(data[s * D : (s + 1) * D]) for s in range(N)]
        assert scores[0] == 0.0, f"{name}: first sample must score 0 (denom=1, count clamp)"
        assert all(math.isfinite(s) for s in scores), name
        ref = refs[name](det, data, D)
        worst = max(abs(a - b) for a, b in zip(scores, ref))
        assert worst < 1e-4, f"{name}: f32 port drifts {worst} from the f64 reference"
        path = os.path.join(out_dir, f"golden_{name}.txt")
        with open(path, "w") as fh:
            fh.write(f"# golden scores: {name} r={R} d={D} seed={DET_SEED} window={WINDOW}\n")
            fh.write(f"# stream: {N} samples, Prng({STREAM_SEED}) unit gaussians, warmup={WARMUP_SAMPLES}\n")
            for s in scores:
                fh.write(f"{s:.9g}\n")
        print(f"{name}: wrote {N} scores to {path} (max |f32-f64 ref| = {worst:.2e})")


if __name__ == "__main__":
    main()
