"""L2 streaming models vs per-sample numpy references.

Checks the full ①–⑦ pipeline: scores, state evolution, chunk-boundary
equivalence (two chunks == one stream), mask/padding semantics and Q16.16
quantisation.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.model import (
    DetectorConfig, loda_chunk, loda_init_state,
    rshash_chunk, xstream_chunk, cms_init_state,
)
from compile.kernels import ref as kref


def _cfg(c=16, d=3, r=4, window=8, quantize=False):
    return DetectorConfig(d=d, r=r, chunk=c, window=window,
                          bins=5, w=2, mod=32, k=4, quantize=quantize)


def _loda_params(rng, cfg):
    prj = rng.normal(size=(cfg.r, cfg.d)).astype(np.float32)
    pmin = np.full(cfg.r, -4, np.float32)
    pmax = np.full(cfg.r, 4, np.float32)
    return prj, pmin, pmax


def _rshash_params(rng, cfg, x):
    dmin = x.min(axis=0)
    dmax = x.max(axis=0)
    alpha = rng.uniform(0, 1, size=(cfg.r, cfg.d)).astype(np.float32)
    f = rng.uniform(0.2, 0.8, size=cfg.r).astype(np.float32)
    return dmin, dmax, alpha, f


def _xstream_params(rng, cfg):
    proj = rng.normal(size=(cfg.r, cfg.d, cfg.k)).astype(np.float32)
    shift = rng.uniform(0, 1, size=(cfg.r, cfg.w, cfg.k)).astype(np.float32)
    width = rng.uniform(0.5, 2, size=(cfg.r, cfg.k)).astype(np.float32)
    return proj, shift, width


def _run(detector, cfg, x, mask, params, state, use_ref=False):
    fn = {"loda": loda_chunk, "rshash": rshash_chunk, "xstream": xstream_chunk}[detector]
    return fn(cfg, jnp.asarray(x), jnp.asarray(mask), *params, *state, use_ref=use_ref)


def _streaming_ref(detector, cfg, params):
    if detector == "loda":
        return kref.StreamingLodaRef(*params, cfg.bins, cfg.window)
    if detector == "rshash":
        return kref.StreamingRsHashRef(*params, cfg.w, cfg.mod, cfg.window)
    return kref.StreamingXStreamRef(*params, cfg.w, cfg.mod, cfg.window)


@pytest.mark.parametrize("detector", ["loda", "rshash", "xstream"])
@pytest.mark.parametrize("use_ref", [False, True], ids=["pallas", "jnp-ref"])
def test_chunk_matches_per_sample_reference(detector, use_ref):
    cfg = _cfg(c=24, window=8)
    rng = np.random.default_rng(7)
    x = rng.normal(size=(cfg.chunk, cfg.d)).astype(np.float32)
    mask = np.ones(cfg.chunk, np.float32)
    if detector == "loda":
        params = _loda_params(rng, cfg)
        state = loda_init_state(cfg)
    elif detector == "rshash":
        params = _rshash_params(rng, cfg, x)
        state = cms_init_state(cfg)
    else:
        params = _xstream_params(rng, cfg)
        state = cms_init_state(cfg)
    out = _run(detector, cfg, x, mask, params, state, use_ref)
    ref = _streaming_ref(detector, cfg, params)
    want = np.array([ref.update(xi) for xi in x])
    np.testing.assert_allclose(np.asarray(out[0]), want, atol=1e-5)
    # State parity: count table and ring identical, window invariant holds.
    np.testing.assert_array_equal(np.asarray(out[1]),
                                  ref.hist if detector == "loda" else ref.cms)
    table = np.asarray(out[1])
    per_det_total = table.reshape(cfg.r, -1).sum(axis=1)
    expect = min(cfg.chunk, cfg.window) * (1 if detector == "loda" else cfg.w)
    assert (per_det_total == expect).all()


@pytest.mark.parametrize("detector", ["loda", "rshash", "xstream"])
def test_two_chunks_equal_one_stream(detector):
    """State threading across executable invocations is exact."""
    rng = np.random.default_rng(3)
    d = 3
    full_cfg = _cfg(c=20, d=d, window=6)
    half_cfg = _cfg(c=10, d=d, window=6)
    x = rng.normal(size=(20, d)).astype(np.float32)
    ones = np.ones(20, np.float32)
    if detector == "loda":
        params = _loda_params(rng, full_cfg)
        init = lambda cfg: loda_init_state(cfg)
    elif detector == "rshash":
        params = _rshash_params(rng, full_cfg, x)
        init = lambda cfg: cms_init_state(cfg)
    else:
        params = _xstream_params(rng, full_cfg)
        init = lambda cfg: cms_init_state(cfg)

    out_full = _run(detector, full_cfg, x, ones, params, init(full_cfg))
    o1 = _run(detector, half_cfg, x[:10], ones[:10], params, init(half_cfg))
    o2 = _run(detector, half_cfg, x[10:], ones[10:], params, o1[1:])
    got = np.concatenate([np.asarray(o1[0]), np.asarray(o2[0])])
    np.testing.assert_allclose(got, np.asarray(out_full[0]), atol=1e-6)
    for a, b in zip(out_full[1:], o2[1:]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("detector", ["loda", "rshash", "xstream"])
def test_masked_tail_does_not_touch_state(detector):
    """Padded samples in the final chunk must not score or mutate state."""
    rng = np.random.default_rng(11)
    cfg = _cfg(c=16, window=8)
    x = rng.normal(size=(cfg.chunk, cfg.d)).astype(np.float32)
    mask = np.ones(cfg.chunk, np.float32)
    mask[10:] = 0.0
    # Poison the padded region: masked garbage must be inert.
    x[10:] = 1e9
    if detector == "loda":
        params = _loda_params(rng, cfg)
        state = loda_init_state(cfg)
    elif detector == "rshash":
        params = _rshash_params(rng, cfg, x[:10])
        state = cms_init_state(cfg)
    else:
        params = _xstream_params(rng, cfg)
        state = cms_init_state(cfg)
    out = _run(detector, cfg, x, mask, params, state)
    scores = np.asarray(out[0])
    assert (scores[10:] == 0).all()
    assert int(np.asarray(out[4])[0]) == 10       # n counts valid samples only
    ref = _streaming_ref(detector, cfg, params)
    want = np.array([ref.update(xi) for xi in x[:10]])
    np.testing.assert_allclose(scores[:10], want, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(out[1]),
                                  ref.hist if detector == "loda" else ref.cms)


def test_quantized_scores_are_q16_16():
    rng = np.random.default_rng(5)
    cfg = _cfg(c=16, quantize=True)
    x = rng.normal(size=(cfg.chunk, cfg.d)).astype(np.float32)
    mask = np.ones(cfg.chunk, np.float32)
    params = _loda_params(rng, cfg)
    out = _run("loda", cfg, x, mask, params, loda_init_state(cfg))
    scores = np.asarray(out[0], np.float64)
    np.testing.assert_allclose(scores * 65536.0, np.round(scores * 65536.0), atol=1e-3)
    # Quantised and float scores agree to 2^-16-ish.
    cfg_f = _cfg(c=16, quantize=False)
    out_f = _run("loda", cfg_f, x, mask, params, loda_init_state(cfg_f))
    np.testing.assert_allclose(scores, np.asarray(out_f[0]), atol=1.0 / 65536.0)


@settings(max_examples=10)
@given(st.integers(0, 2**31), st.integers(1, 12), st.integers(2, 10))
def test_window_eviction_bounds_counts(seed, c, window):
    """Property: no count may exceed the window length, none may go negative."""
    rng = np.random.default_rng(seed)
    cfg = _cfg(c=c, window=window)
    x = rng.normal(size=(cfg.chunk, cfg.d)).astype(np.float32)
    mask = np.ones(cfg.chunk, np.float32)
    params = _loda_params(rng, cfg)
    out = _run("loda", cfg, x, mask, params, loda_init_state(cfg))
    hist = np.asarray(out[1])
    assert (hist >= 0).all() and (hist <= window).all()
    assert hist.sum(axis=1).max() <= window


@pytest.mark.parametrize("detector", ["rshash", "xstream"])
def test_scores_nonnegative_and_finite(detector):
    rng = np.random.default_rng(2)
    cfg = _cfg(c=32, window=8)
    x = rng.normal(size=(cfg.chunk, cfg.d)).astype(np.float32)
    mask = np.ones(cfg.chunk, np.float32)
    if detector == "rshash":
        params = _rshash_params(rng, cfg, x)
    else:
        params = _xstream_params(rng, cfg)
    out = _run(detector, cfg, x, mask, params, cms_init_state(cfg))
    s = np.asarray(out[0])
    assert np.isfinite(s).all()
