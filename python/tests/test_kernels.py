"""L1 Pallas kernels vs pure-jnp oracles (the CORE correctness signal).

Hypothesis sweeps chunk size, ensemble size, dimensionality and value ranges;
every kernel output must equal the ref bit-for-bit (indices are integers, and
the float math is identical op-for-op).
"""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, strategies as st

from compile.kernels import loda_frontend, rshash_frontend, xstream_frontend
from compile.kernels import ref as kref

dims = st.integers(1, 24)
chunks = st.integers(1, 16)
ensembles = st.integers(1, 12)


def _data(seed, c, d, scale=10.0):
    rng = np.random.default_rng(seed)
    return (rng.normal(size=(c, d)) * scale).astype(np.float32), rng


@given(chunks, dims, ensembles, st.integers(2, 40), st.integers(0, 2**31))
def test_loda_kernel_matches_ref(c, d, r, bins, seed):
    x, rng = _data(seed, c, d)
    prj = rng.normal(size=(r, d)).astype(np.float32)
    pmin = rng.normal(size=r).astype(np.float32) - 5
    pmax = pmin + rng.uniform(0.5, 10, size=r).astype(np.float32)
    got = np.asarray(loda_frontend(jnp.asarray(x), prj, pmin, pmax, bins=bins))
    want = np.asarray(kref.loda_frontend_ref(jnp.asarray(x), prj, pmin, pmax, bins))
    np.testing.assert_array_equal(got, want)
    assert got.dtype == np.int32
    assert (got >= 0).all() and (got < bins).all()


@given(chunks, dims, ensembles, st.integers(1, 4), st.sampled_from([16, 64, 128]),
       st.integers(0, 2**31))
def test_rshash_kernel_matches_ref(c, d, r, w, mod, seed):
    x, rng = _data(seed, c, d)
    dmin = x.min(axis=0) - 0.1
    dmax = x.max(axis=0) + 0.1
    alpha = rng.uniform(0, 1, size=(r, d)).astype(np.float32)
    f = rng.uniform(0.1, 0.9, size=r).astype(np.float32)
    got = np.asarray(rshash_frontend(jnp.asarray(x), dmin, dmax, alpha, f, w=w, mod=mod))
    want = np.asarray(kref.rshash_frontend_ref(jnp.asarray(x), dmin, dmax, alpha, f, w, mod))
    np.testing.assert_array_equal(got, want)
    assert (got >= 0).all() and (got < mod).all()


@given(chunks, dims, st.integers(1, 6), st.integers(1, 3), st.integers(1, 8),
       st.integers(0, 2**31))
def test_xstream_kernel_matches_ref(c, d, r, w, k, seed):
    mod = 128
    x, rng = _data(seed, c, d, scale=3.0)
    proj = rng.normal(size=(r, d, k)).astype(np.float32)
    shift = rng.uniform(0, 1, size=(r, w, k)).astype(np.float32)
    width = rng.uniform(0.5, 4.0, size=(r, k)).astype(np.float32)
    got = np.asarray(xstream_frontend(jnp.asarray(x), proj, shift, width, w=w, mod=mod))
    want = np.asarray(kref.xstream_frontend_ref(jnp.asarray(x), proj, shift, width, w, mod))
    np.testing.assert_array_equal(got, want)
    assert (got >= 0).all() and (got < mod).all()


def test_loda_clips_out_of_range_projections():
    # Samples far outside [pmin, pmax] must clip to the edge bins, never wrap.
    x = np.array([[1e6], [-1e6]], np.float32)
    prj = np.ones((1, 1), np.float32)
    idx = np.asarray(loda_frontend(jnp.asarray(x), prj,
                                   np.zeros(1, np.float32), np.ones(1, np.float32),
                                   bins=20))
    assert idx[0, 0] == 19 and idx[1, 0] == 0


def test_rshash_degenerate_span_is_finite():
    # A constant feature (dmin == dmax) must not produce NaN/inf indices.
    x = np.ones((4, 2), np.float32)
    dmin = np.array([1.0, 0.0], np.float32)
    dmax = np.array([1.0, 2.0], np.float32)
    alpha = np.full((3, 2), 0.5, np.float32)
    f = np.full(3, 0.5, np.float32)
    idx = np.asarray(rshash_frontend(jnp.asarray(x), dmin, dmax, alpha, f, w=2, mod=64))
    assert (idx >= 0).all() and (idx < 64).all()


def test_xstream_kernel_f32_dtype_and_shape():
    c, d, r, w, k = 5, 3, 2, 2, 4
    rng = np.random.default_rng(0)
    x = rng.normal(size=(c, d)).astype(np.float32)
    proj = rng.normal(size=(r, d, k)).astype(np.float32)
    shift = rng.uniform(size=(r, w, k)).astype(np.float32)
    width = rng.uniform(0.5, 1, size=(r, k)).astype(np.float32)
    out = xstream_frontend(jnp.asarray(x), proj, shift, width, w=w, mod=32)
    assert out.shape == (c, r, w) and out.dtype == jnp.int32
