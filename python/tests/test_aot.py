"""AOT path: every variant kind lowers to loadable HLO text; the manifest is
well-formed and enumerates every artifact the rust coordinator expects."""

import pytest

from compile.manifest import Variant, default_variants, PBLOCK_R, DATASET_DIMS
from compile.aot import lower_variant


SMALL = dict(chunk=8, window=4, bins=5, w=2, mod=16, k=3)


@pytest.mark.parametrize("kind", ["loda", "rshash", "xstream"])
def test_detector_variant_lowers(kind):
    v = Variant(kind=kind, d=3, r=2, **SMALL)
    text = lower_variant(v)
    assert text.startswith("HloModule")
    # 5-tuple output: scores + 4 state arrays.
    assert "->(f32[8]{0}, " in text.replace("\n", "")


@pytest.mark.parametrize("combo", ["avg", "max", "wavg", "or", "vote"])
def test_combo_variant_lowers(combo):
    v = Variant(kind="combo", combo=combo, chunk=8)
    text = lower_variant(v)
    assert text.startswith("HloModule")


def test_bypass_variant_lowers():
    text = lower_variant(Variant(kind="bypass", d=3, chunk=8))
    assert "f32[8,3]" in text


def test_manifest_covers_all_pblock_detectors():
    names = {v.name for v in default_variants()}
    for kind, r in PBLOCK_R.items():
        for d in DATASET_DIMS:
            assert f"{kind}_d{d}_r{r}" in names
    for combo in ("avg", "max", "wavg", "or", "vote"):
        assert f"combo_{combo}" in names
    assert "bypass_d1" in names


def test_manifest_lines_parse_as_kv():
    for v in default_variants():
        toks = v.manifest_line().split()
        kv = dict(t.split("=", 1) for t in toks)
        assert kv["name"] == v.name
        assert kv["file"] == f"{v.name}.hlo.txt"
        assert int(kv["chunk"]) > 0
        assert kv["kind"] in ("loda", "rshash", "xstream", "bypass", "combo")


def test_variant_names_are_unique():
    names = [v.name for v in default_variants()]
    assert len(names) == len(set(names))
