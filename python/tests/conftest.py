import os
import sys

# Allow `import compile.*` when pytest is invoked from python/ or repo root.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from hypothesis import settings

# Pallas interpret mode is slow; keep sweeps tight but meaningful.
settings.register_profile("fsead", max_examples=20, deadline=None)
settings.load_profile("fsead")
