"""Combo RMs (paper Table 2): averaging / maximization / weighted average
for scores, OR / voting for labels."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, strategies as st

from compile.model import combo_avg, combo_max, combo_wavg, combo_or, combo_vote


def _scores(seed, c=8):
    rng = np.random.default_rng(seed)
    return rng.normal(size=(c, 4)).astype(np.float32)


@given(st.integers(0, 2**31), st.integers(1, 4))
def test_avg_matches_numpy(seed, k):
    s = _scores(seed)
    active = np.zeros(4, np.float32)
    active[:k] = 1
    got = np.asarray(combo_avg(jnp.asarray(s), jnp.asarray(active))[0])
    np.testing.assert_allclose(got, s[:, :k].mean(axis=1), rtol=1e-6)


@given(st.integers(0, 2**31), st.integers(1, 4))
def test_max_matches_numpy(seed, k):
    s = _scores(seed)
    active = np.zeros(4, np.float32)
    active[:k] = 1
    got = np.asarray(combo_max(jnp.asarray(s), jnp.asarray(active))[0])
    np.testing.assert_allclose(got, s[:, :k].max(axis=1), rtol=1e-6)


@given(st.integers(0, 2**31))
def test_wavg_weights_renormalise_over_active(seed):
    rng = np.random.default_rng(seed)
    s = _scores(seed)
    w = rng.uniform(0.1, 1, size=4).astype(np.float32)
    active = np.array([1, 1, 0, 1], np.float32)
    got = np.asarray(combo_wavg(jnp.asarray(s), jnp.asarray(active), jnp.asarray(w))[0])
    aw = active * w
    want = (s * aw).sum(axis=1) / aw.sum()
    np.testing.assert_allclose(got, want, rtol=1e-5)


def test_wavg_equal_weights_is_avg():
    s = _scores(1)
    active = np.ones(4, np.float32)
    w = np.full(4, 0.25, np.float32)
    a = np.asarray(combo_avg(jnp.asarray(s), jnp.asarray(active))[0])
    b = np.asarray(combo_wavg(jnp.asarray(s), jnp.asarray(active), jnp.asarray(w))[0])
    np.testing.assert_allclose(a, b, rtol=1e-5)


@given(st.integers(0, 2**31), st.integers(1, 4))
def test_or_is_any(seed, k):
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 2, size=(8, 4)).astype(np.float32)
    active = np.zeros(4, np.float32)
    active[:k] = 1
    got = np.asarray(combo_or(jnp.asarray(labels), jnp.asarray(active))[0])
    np.testing.assert_array_equal(got, labels[:, :k].max(axis=1))


def test_vote_majority_with_tie_to_anomaly():
    labels = np.array([
        [1, 1, 0, 0],   # 2/4 tie → anomaly
        [1, 0, 0, 0],   # 1/4 → normal
        [1, 1, 1, 0],   # 3/4 → anomaly
        [0, 0, 0, 0],   # 0/4 → normal
    ], np.float32)
    active = np.ones(4, np.float32)
    got = np.asarray(combo_vote(jnp.asarray(labels), jnp.asarray(active))[0])
    np.testing.assert_array_equal(got, [1, 0, 1, 0])


def test_vote_respects_active_mask():
    labels = np.array([[1, 1, 0, 0]], np.float32)
    active = np.array([1, 1, 0, 0], np.float32)   # quorum = 2, votes = 2 → anomaly
    got = np.asarray(combo_vote(jnp.asarray(labels), jnp.asarray(active))[0])
    assert got[0] == 1
