"""Jenkins one-at-a-time hash: bit-exactness (paper Algorithm 4).

The same sequence is implemented three times — jnp (kernels.jenkins), numpy
(kernels.ref._jenkins_np) and rust (detectors/jenkins.rs). The golden vectors
below are shared verbatim with the rust unit tests; any drift breaks parity
between the CPU baseline and the FPGA artifacts.
"""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, strategies as st

from compile.kernels.jenkins import jenkins_hash, jenkins_mod
from compile.kernels.ref import _jenkins_np

# (key_words, seed, expected_hash) — keep in sync with rust::detectors::jenkins tests.
GOLDEN = [
    ([0], 0, 0x00000000),
    ([1, 2, 3], 1, 0x54EE7BFA),
    ([0xFFFFFFFF], 7, 0x6DC75B8D),
    ([42, 0, 42, 0xDEADBEEF], 2, 0x1FF9CDF1),
    ([5, 4, 3, 2, 1, 0], 123456, 0x1C57948C),
]


def test_golden_vectors_numpy():
    for key, seed, want in GOLDEN:
        got = int(_jenkins_np(np.array(key, np.uint32), seed))
        assert got == want, f"key={key} seed={seed}: got {got:#x}, want {want:#x}"


def test_golden_vectors_jnp():
    for key, seed, want in GOLDEN:
        got = int(jenkins_hash(jnp.array([key], jnp.uint32), seed)[0])
        assert got == want, f"key={key} seed={seed}: got {got:#x}, want {want:#x}"


@given(
    st.lists(st.integers(0, 2**32 - 1), min_size=1, max_size=24),
    st.integers(0, 2**32 - 1),
)
def test_jnp_matches_numpy(key, seed):
    a = int(jenkins_hash(jnp.array([key], jnp.uint32), seed)[0])
    b = int(_jenkins_np(np.array(key, np.uint32), seed))
    assert a == b


@given(
    st.integers(1, 8),       # batch
    st.integers(1, 8),       # key length
    st.integers(0, 31),      # seed
    st.integers(0, 2**31),   # data seed
)
def test_vectorised_equals_rowwise(b, l, seed, data_seed):
    rng = np.random.default_rng(data_seed)
    keys = rng.integers(0, 2**32, size=(b, l), dtype=np.uint32)
    vec = np.asarray(jenkins_hash(jnp.asarray(keys), seed))
    for i in range(b):
        assert vec[i] == _jenkins_np(keys[i], seed)


@given(st.integers(1, 6), st.integers(1, 10))
def test_mod_in_range(l, mod):
    rng = np.random.default_rng(l * 31 + mod)
    keys = rng.integers(-(2**31), 2**31, size=(5, l), dtype=np.int64).astype(np.int32)
    idx = np.asarray(jenkins_mod(jnp.asarray(keys), 1, mod))
    assert idx.dtype == np.int32
    assert (idx >= 0).all() and (idx < mod).all()


def test_negative_int32_keys_wrap_like_u32():
    # int32 -1 must hash identically to uint32 0xFFFFFFFF (rust `as u32`).
    a = int(jenkins_hash(jnp.array([[-1]], jnp.int32), 7)[0])
    b = int(jenkins_hash(jnp.array([[0xFFFFFFFF]], jnp.uint32), 7)[0])
    assert a == b
