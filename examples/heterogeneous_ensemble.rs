//! Heterogeneous ensemble (paper Fig 7d): Loda + RS-Hash + xStream pblocks
//! on one stream, aggregated per algorithm by combo pblocks, with label
//! combination on the host — the composition fSEAD exists to make easy.
//!
//! ```sh
//! cargo run --release --example heterogeneous_ensemble
//! ```

use anyhow::Result;
use fsead::combine::LabelCombiner;
use fsead::config::FseadConfig;
use fsead::data::Dataset;
use fsead::exp::score_label_auc;
use fsead::fabric::Fabric;
use fsead::metrics::{auc::auc_labels, labels_from_scores, normalize_scores};

fn main() -> Result<()> {
    let ds = Dataset::load("shuttle", 7, None).unwrap().prefix(20_000);
    let contamination = ds.contamination();
    let truth = ds.labels.clone();
    println!("dataset: {} prefix — n={}, d={}", ds.name, ds.n(), ds.d);

    // Fig 7(d): Loda×3 → COMBO1, RS-Hash×2 → COMBO2, xStream×2 → COMBO3.
    let mut cfg = FseadConfig::fig7d();
    cfg.use_fpga = std::path::Path::new("artifacts/manifest.txt").exists();
    let mut fabric = Fabric::new(cfg, vec![ds])?;
    for (id, rm) in fabric.assignments() {
        println!("  RP-{id}: {rm}");
    }
    let out = fabric.run()?;
    println!(
        "pass: {:.1} ms wall, modelled FPGA {:.1} ms, {} switch flits",
        out.wall_secs * 1e3,
        out.modeled_fpga_secs * 1e3,
        out.switch_flits
    );

    // Per-algorithm quality from the three combo outputs.
    let names = ["loda×3", "rshash×2", "xstream×2"];
    let mut label_streams = Vec::new();
    for (i, (id, scores)) in out.combo_scores.iter().enumerate() {
        let (auc_s, auc_l) = score_label_auc(scores, &truth, contamination);
        println!("combo {id} ({}): AUC-S {auc_s:.4}  AUC-L {auc_l:.4}", names[i]);
        label_streams.push(labels_from_scores(&normalize_scores(scores), contamination));
    }

    // Cross-algorithm label combination (paper Table 5's OR / voting).
    let views: Vec<&[bool]> = label_streams.iter().map(|v| v.as_slice()).collect();
    for (name, combiner) in [("OR", LabelCombiner::Or), ("voting", LabelCombiner::Voting)] {
        let combined = combiner.combine(&views);
        println!("{name:>7} of all three algorithms: AUC-L {:.4}", auc_labels(&combined, &truth));
    }
    Ok(())
}
