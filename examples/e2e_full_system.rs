//! END-TO-END SYSTEM DRIVER (the EXPERIMENTS.md validation run).
//!
//! Exercises every layer on a real small workload, proving the stack
//! composes: L1 Pallas kernels + L2 JAX scan models (inside the AOT
//! artifacts), the PJRT device service, and the L3 fabric — pblocks, both
//! switches, combos, DFX reconfiguration — serving batched streaming
//! requests, with quality (ROC-AUC vs CPU baseline), latency and throughput
//! reported per phase.
//!
//! ```sh
//! make artifacts && cargo run --release --example e2e_full_system
//! ```
//! Falls back to CPU-native RMs if artifacts are missing (still end-to-end
//! through the fabric, but without the PJRT layer).

use anyhow::Result;
use std::time::Instant;

use fsead::config::{ComboCfg, FseadConfig, PblockCfg, RmKind};
use fsead::data::Dataset;
use fsead::detectors::{DetectorKind, DetectorSpec};
use fsead::ensemble::{run_batched, run_threaded};
use fsead::exp::score_label_auc;
use fsead::fabric::Fabric;
use fsead::hw::timing::FpgaTimingModel;

fn main() -> Result<()> {
    let use_fpga = std::path::Path::new("artifacts/manifest.txt").exists();
    let cap: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(20_000);
    println!("=== fSEAD end-to-end system validation (fpga={use_fpga}, cap={cap}) ===\n");

    // ---- Workload: the paper's cardio + shuttle streams.
    let cardio = Dataset::load("cardio", 42, None).unwrap();
    let shuttle = Dataset::load("shuttle", 42, None).unwrap().prefix(cap);
    println!(
        "workloads: cardio n={} d={}, shuttle n={} d={}",
        cardio.n(),
        cardio.d,
        shuttle.n(),
        shuttle.d
    );

    // ---- Phase 1: heterogeneous composition on cardio (Fig 7d).
    println!("\n-- phase 1: Fig 7(d) heterogeneous ensemble on cardio --");
    let mut cfg = FseadConfig::fig7d();
    cfg.use_fpga = use_fpga;
    let truth = cardio.labels.clone();
    let cont = cardio.contamination();
    let mut fabric = Fabric::new(cfg, vec![cardio.clone()])?;
    let t0 = Instant::now();
    let out = fabric.run()?;
    let wall = t0.elapsed().as_secs_f64();
    let names = ["loda*3", "rshash*2", "xstream*2"];
    for (i, (id, scores)) in out.combo_scores.iter().enumerate() {
        let (auc_s, auc_l) = score_label_auc(scores, &truth, cont);
        println!("  combo {id} ({}): AUC-S {auc_s:.4} AUC-L {auc_l:.4}", names[i]);
    }
    println!(
        "  latency: wall {:.1} ms | modelled FPGA {:.1} ms | throughput {:.0} samples/s",
        wall * 1e3,
        out.modeled_fpga_secs * 1e3,
        cardio.n() as f64 / wall
    );
    if let Some(st) = fabric.runtime_stats() {
        println!(
            "  device: {} invocations, {:.1} ms device time, {:.3} ms/invocation",
            st.executions,
            st.execute_secs * 1e3,
            st.execute_secs * 1e3 / st.executions.max(1) as f64
        );
    }

    // ---- Phase 2: DFX reconfiguration to homogeneous Loda on shuttle.
    println!("\n-- phase 2: run-time DFX swap to Fig 7(c) homogeneous loda on shuttle --");
    let streams = vec![shuttle.clone()];
    let mut cfg = FseadConfig::fig7c(DetectorKind::Loda);
    cfg.use_fpga = use_fpga;
    let mut fabric = Fabric::new(cfg, streams)?;
    // Demonstrate one live swap (loda → xstream → loda) with the DFX model.
    let rep = fabric.reconfigure(7, RmKind::Detector(DetectorKind::XStream), 20, 0)?;
    println!("  DFX RP-7: {} -> {} (model {:.1} ms)", rep.from, rep.to, rep.model_ms);
    let rep = fabric.reconfigure(7, RmKind::Detector(DetectorKind::Loda), 35, 0)?;
    println!("  DFX RP-7: {} -> {} (model {:.1} ms)", rep.from, rep.to, rep.model_ms);

    let truth = shuttle.labels.clone();
    let cont = shuttle.contamination();
    let t0 = Instant::now();
    let out = fabric.run()?;
    let wall = t0.elapsed().as_secs_f64();
    let n = out.combo_scores[&1].len();
    let mut combined = vec![0f32; n];
    for (c, (a, b)) in combined
        .iter_mut()
        .zip(out.combo_scores[&1].iter().zip(out.combo_scores[&2].iter()))
    {
        *c = (4.0 * a + 3.0 * b) / 7.0;
    }
    let (auc_s, auc_l) = score_label_auc(&combined, &truth, cont);
    println!("  245-subdetector loda: AUC-S {auc_s:.4} AUC-L {auc_l:.4}");
    println!(
        "  latency: wall {:.1} ms | modelled FPGA {:.1} ms | throughput {:.0} samples/s",
        wall * 1e3,
        out.modeled_fpga_secs * 1e3,
        shuttle.n() as f64 / wall
    );

    // ---- Phase 3: CPU baseline comparison (the paper's headline claim),
    //      in both execution modes: the paper-faithful lock-step runner and
    //      the lock-free batched fast path.
    println!("\n-- phase 3: CPU baseline (4 threads, paper §4.4) --");
    let spec = DetectorSpec::new(DetectorKind::Loda, shuttle.d, 245, 42);
    let t0 = Instant::now();
    let cpu_scores = run_threaded(&spec, &shuttle, 4);
    let cpu_wall = t0.elapsed().as_secs_f64();
    let (cpu_auc, _) = score_label_auc(&cpu_scores, &truth, cont);
    let model = FpgaTimingModel::default();
    let fpga_model = model.exec_time_s(DetectorKind::Loda, shuttle.n(), shuttle.d);
    println!(
        "  CPU: {:.1} ms (AUC-S {cpu_auc:.4}) | FPGA model: {:.1} ms | speed-up {:.2}x (paper: 4.29x on full shuttle)",
        cpu_wall * 1e3,
        fpga_model * 1e3,
        cpu_wall / fpga_model
    );
    let t0 = Instant::now();
    let fast_scores = run_batched(&spec, &shuttle, 4);
    let fast_wall = t0.elapsed().as_secs_f64();
    let (fast_auc, _) = score_label_auc(&fast_scores, &truth, cont);
    println!(
        "  CPU batched fast path: {:.1} ms (AUC-S {fast_auc:.4}) | {:.2}x vs lock-step | {:.0} samples/s",
        fast_wall * 1e3,
        cpu_wall / fast_wall,
        shuttle.n() as f64 / fast_wall
    );
    println!(
        "  AUC agreement fabric vs CPU: |Δ| = {:.4}",
        (auc_s - cpu_auc).abs()
    );

    println!("\n=== all layers composed: L1/L2 artifacts -> PJRT device -> L3 fabric ===");
    Ok(())
}
