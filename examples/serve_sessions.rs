//! Streaming session serving (paper Fig 7a, as a long-running service):
//! a persistent `FabricServer` keeps three heterogeneous detector
//! partitions resident while independent clients open sessions, stream
//! their sensor data chunk by chunk with bounded-inbox backpressure,
//! collect scores asynchronously, and close — the partitions are then
//! reused by the next wave of clients, and one session is live-reshaped
//! mid-stream by an in-flight DFX swap.
//!
//! ```sh
//! cargo run --release --example serve_sessions
//! ```

use anyhow::Result;
use fsead::config::{FseadConfig, PblockCfg, RmKind};
use fsead::data::synth::{generate_profile, DatasetProfile};
use fsead::detectors::DetectorKind;
use fsead::exp::score_label_auc;
use fsead::fabric::server::{FabricServer, SessionSpec};

fn main() -> Result<()> {
    let mut cfg = FseadConfig {
        use_fpga: std::path::Path::new("artifacts/manifest.txt").exists(),
        chunk: 64,
        ..FseadConfig::default()
    };
    let kinds = [DetectorKind::Loda, DetectorKind::RsHash, DetectorKind::XStream];
    for (i, kind) in kinds.iter().enumerate() {
        cfg.pblocks.push(PblockCfg {
            id: i + 1,
            rm: RmKind::Detector(*kind),
            r: 8,
            stream: 0,
            lanes: 0,
        });
    }
    let window = cfg.hyper.window;
    let server = FabricServer::start(cfg)?;
    println!(
        "server up: {} resident partitions ({})",
        server.partitions().len(),
        kinds.iter().map(|k| k.as_str()).collect::<Vec<_>>().join(", ")
    );

    // ---- Wave 1: three concurrent clients, one session each. Client 0
    //      additionally hot-swaps its partition's detector mid-stream.
    std::thread::scope(|scope| {
        let server = &server;
        let mut handles = Vec::new();
        for client in 0..3usize {
            handles.push(scope.spawn(move || -> Result<()> {
                let profile = DatasetProfile {
                    name: "sensor",
                    n: 4_000 + client * 500,
                    d: 3,
                    outliers: 60 + client * 20,
                    clusters: 2,
                };
                let ds = generate_profile(&profile, 300 + client as u64);
                let mut session = server.open(SessionSpec::for_dataset(&ds, window))?;
                let pblock = session.pblock();
                if client == 0 {
                    // Live DFX while the session streams: swap this
                    // partition to RS-Hash at flit 20 (dark window from the
                    // Table-13 model at the configured stream rate).
                    let (model_ms, dark) = server.schedule_swap(
                        pblock,
                        20,
                        RmKind::Detector(DetectorKind::RsHash),
                        8,
                        Some(2),
                    )?;
                    println!(
                        "  client {client}: armed mid-session swap on RP-{pblock} \
                         (model {model_ms:.1} ms → {dark} dark flits)"
                    );
                }
                let mut scores = Vec::new();
                for block in ds.data.chunks(64 * ds.d * 4) {
                    session.push(block)?;
                    scores.extend(session.poll_scores());
                }
                let closed = session.close()?;
                scores.extend(closed.scores);
                let (auc_s, _) = score_label_auc(&scores, &ds.labels, ds.contamination());
                println!(
                    "  client {client} on RP-{pblock}: {} samples in {} flits, AUC-S {auc_s:.4}{}",
                    closed.samples,
                    closed.flits,
                    if closed.padded_tail {
                        format!(" (tail padded at {} rows)", closed.tail_valid)
                    } else {
                        String::new()
                    }
                );
                for ev in &closed.swap_events {
                    println!("    swap: {ev}");
                }
                Ok(())
            }));
        }
        for h in handles {
            h.join().expect("client thread panicked")?;
        }
        Ok::<(), anyhow::Error>(())
    })?;

    // ---- Wave 2: the partitions are immediately reusable — a quick burst
    //      of short sessions churns through the free pool.
    for round in 0..4usize {
        let ds = generate_profile(
            &DatasetProfile { name: "burst", n: 1_000, d: 3, outliers: 20, clusters: 2 },
            600 + round as u64,
        );
        let mut session = server.open(SessionSpec::for_dataset(&ds, window))?;
        let pblock = session.pblock();
        session.push(&ds.data)?;
        let closed = session.close()?;
        println!("  burst session {round} on RP-{pblock}: {} scores", closed.scores.len());
    }

    let report = server.shutdown()?;
    println!("server closed after {} sessions", report.sessions_served);
    Ok(())
}
