//! Quickstart: stream one dataset through a single detector pblock and
//! print anomaly-detection quality and throughput.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```
//! Uses the PJRT "FPGA" path when `make artifacts` has been run, else the
//! CPU-native fallback.

use anyhow::Result;
use fsead::config::{FseadConfig, PblockCfg, RmKind};
use fsead::data::Dataset;
use fsead::detectors::DetectorKind;
use fsead::fabric::Fabric;
use fsead::metrics::{auc_roc, normalize_scores};

fn main() -> Result<()> {
    // 1. Load a dataset (synthetic stand-in for the paper's Cardio; drop a
    //    real `cardio.csv` into --data-dir to use it instead).
    let ds = Dataset::load("cardio", 42, None).unwrap();
    println!(
        "dataset: {} — {} samples, {} dims, {:.2}% outliers",
        ds.name,
        ds.n(),
        ds.d,
        ds.contamination() * 100.0
    );

    // 2. Configure a minimal fabric: one pblock running a Loda ensemble.
    let mut cfg = FseadConfig::default();
    cfg.use_fpga = std::path::Path::new("artifacts/manifest.txt").exists();
    cfg.pblocks.push(PblockCfg {
        id: 1,
        rm: RmKind::Detector(DetectorKind::Loda),
        r: DetectorKind::Loda.pblock_r(), // 35 sub-detectors (paper Table 7)
        stream: 0,
        lanes: 0,
    });
    println!("fabric: 1 pblock, loda r=35, fpga={}", cfg.use_fpga);

    // 3. Run the stream through the fabric.
    let truth = ds.labels.clone();
    let mut fabric = Fabric::new(cfg, vec![ds])?;
    let out = fabric.run()?;

    // 4. Score quality + throughput.
    let scores = &out.pblock_scores[&1];
    let auc = auc_roc(&normalize_scores(scores), &truth);
    println!(
        "scored {} samples in {:.1} ms  ({:.0} samples/s wall; modelled FPGA: {:.2} ms)",
        scores.len(),
        out.wall_secs * 1e3,
        scores.len() as f64 / out.wall_secs,
        out.modeled_fpga_secs * 1e3,
    );
    println!("ROC-AUC: {auc:.4}");
    if let Some(stats) = fabric.runtime_stats() {
        println!(
            "device: {} executable invocations, {:.1} ms on device",
            stats.executions,
            stats.execute_secs * 1e3
        );
    }
    Ok(())
}
