//! Run-time reconfiguration (DFX) demo: start with the Fig 7(b) topology
//! (three independent applications), then — without rebuilding anything —
//! swap every pblock to Loda and re-route into the Fig 7(c) maximally
//! parallel homogeneous ensemble. The paper's point: composition changes
//! at run time, not at bitstream-generation time.
//!
//! Phase 3 goes further: **live DFX**. A scripted swap schedule hot-swaps a
//! pblock's detector twice while the stream is playing — the region is
//! quiesced through its decoupler, the Table-13 download latency is charged
//! as a dark window of bypassed flits, and every other pblock keeps
//! streaming untouched.
//!
//! ```sh
//! cargo run --release --example runtime_reconfig
//! ```

use anyhow::Result;
use fsead::config::{ComboCfg, FseadConfig, RmKind};
use fsead::data::Dataset;
use fsead::detectors::DetectorKind;
use fsead::exp::score_label_auc;
use fsead::fabric::Fabric;

fn main() -> Result<()> {
    let use_fpga = std::path::Path::new("artifacts/manifest.txt").exists();
    // Three streams for the three applications of Fig 7(b).
    let streams = vec![
        Dataset::load("cardio", 1, None).unwrap(),
        Dataset::load("shuttle", 2, None).unwrap().prefix(10_000),
        Dataset::load("smtp3", 3, None).unwrap().prefix(10_000),
    ];
    let truths: Vec<Vec<bool>> = streams.iter().map(|d| d.labels.clone()).collect();
    let contamination: Vec<f64> = streams.iter().map(|d| d.contamination()).collect();

    let mut cfg = FseadConfig::fig7b();
    cfg.use_fpga = use_fpga;
    let mut fabric = Fabric::new(cfg, streams)?;

    println!("== phase 1: Fig 7(b) — three independent applications ==");
    for (id, rm) in fabric.assignments() {
        println!("  RP-{id}: {rm}");
    }
    let out = fabric.run()?;
    for (combo, stream) in [(1usize, 0usize), (2, 1), (3, 2)] {
        let (auc_s, _) = score_label_auc(&out.combo_scores[&combo], &truths[stream], contamination[stream]);
        println!("  app {combo}: AUC-S {auc_s:.4}  ({} samples)", out.combo_scores[&combo].len());
    }

    println!("\n== DFX: reconfigure all pblocks to Loda on stream 0 ==");
    let mut total_model_ms = 0.0;
    let mut total_actual_ms = 0.0;
    for id in 1..=7 {
        let rep = fabric.reconfigure(
            id,
            RmKind::Detector(DetectorKind::Loda),
            DetectorKind::Loda.pblock_r(),
            0,
        )?;
        println!(
            "  RP-{id}: {} -> {}  (DFX model {:.1} ms, swap here {:.2} ms)",
            rep.from, rep.to, rep.model_ms, rep.actual_ms
        );
        total_model_ms += rep.model_ms;
        total_actual_ms += rep.actual_ms;
    }
    fabric.set_combos(vec![
        ComboCfg { id: 1, method: "avg".into(), inputs: vec![1, 2, 3, 4], weights: vec![] },
        ComboCfg { id: 2, method: "avg".into(), inputs: vec![5, 6, 7], weights: vec![] },
    ])?;
    println!(
        "  total: modelled DFX downloads {total_model_ms:.0} ms, measured swaps {total_actual_ms:.1} ms"
    );

    println!("\n== phase 2: Fig 7(c) — 245-subdetector homogeneous Loda ensemble ==");
    let out = fabric.run()?;
    let n = out.combo_scores[&1].len();
    let mut combined = vec![0f32; n];
    // Host-side merge of the two combo stages (4+3 pblock weighting).
    for (c, (a, b)) in combined
        .iter_mut()
        .zip(out.combo_scores[&1].iter().zip(out.combo_scores[&2].iter()))
    {
        *c = (4.0 * a + 3.0 * b) / 7.0;
    }
    let (auc_s, auc_l) = score_label_auc(&combined, &truths[0], contamination[0]);
    println!("  cardio with 245 Loda sub-detectors: AUC-S {auc_s:.4}  AUC-L {auc_l:.4}");
    println!("  pass wall {:.1} ms, modelled FPGA {:.1} ms", out.wall_secs * 1e3, out.modeled_fpga_secs * 1e3);

    println!("\n== phase 3: live DFX — scripted hot-swaps against a running stream ==");
    // A dedicated two-pblock fabric at fine flit granularity (chunk 32 →
    // ~58 flits over cardio) so the dark windows are visible in the stats.
    let mut cfg = FseadConfig::default();
    cfg.use_fpga = use_fpga;
    cfg.chunk = 32;
    for id in 1..=2usize {
        cfg.pblocks.push(fsead::config::PblockCfg {
            id,
            rm: RmKind::Detector(DetectorKind::Loda),
            r: 8,
            stream: 0,
            lanes: 0,
        });
    }
    let live_stream = Dataset::load("cardio", 1, None).unwrap();
    let n = live_stream.n();
    let mut live = Fabric::new(cfg, vec![live_stream])?;
    // Schedule: RP-1 → RS-Hash at flit 10, back to Loda at flit 30; RP-2 is
    // never touched and must stream clean through both swaps.
    for (at, kind, r, dark) in [
        (10u64, DetectorKind::RsHash, 8usize, Some(4u64)),
        (30, DetectorKind::Loda, 8, Some(4)),
    ] {
        let (model_ms, dark_flits) = live.schedule_swap(1, at, RmKind::Detector(kind), r, dark)?;
        println!(
            "  armed: RP-1 -> {} @ flit {at} (DFX model {model_ms:.1} ms, dark {dark_flits} flits)",
            kind.as_str()
        );
    }
    let out = live.run()?;
    println!("  streamed {n} samples; dark-window statistics:");
    for ev in &out.swap_events {
        println!("    {ev}");
    }
    let touched = &out.pblock_scores[&1];
    let clean = &out.pblock_scores[&2];
    println!(
        "    RP-1 (swapped twice): {} scores ({} zeroed in dark windows); RP-2 (untouched): {} scores",
        touched.len(),
        touched.iter().filter(|&&s| s == 0.0).count(),
        clean.len()
    );
    Ok(())
}
