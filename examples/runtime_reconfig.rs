//! Run-time reconfiguration (DFX) demo: start with the Fig 7(b) topology
//! (three independent applications), then — without rebuilding anything —
//! swap every pblock to Loda and re-route into the Fig 7(c) maximally
//! parallel homogeneous ensemble. The paper's point: composition changes
//! at run time, not at bitstream-generation time.
//!
//! ```sh
//! cargo run --release --example runtime_reconfig
//! ```

use anyhow::Result;
use fsead::config::{ComboCfg, FseadConfig, RmKind};
use fsead::data::Dataset;
use fsead::detectors::DetectorKind;
use fsead::exp::score_label_auc;
use fsead::fabric::Fabric;

fn main() -> Result<()> {
    let use_fpga = std::path::Path::new("artifacts/manifest.txt").exists();
    // Three streams for the three applications of Fig 7(b).
    let streams = vec![
        Dataset::load("cardio", 1, None).unwrap(),
        Dataset::load("shuttle", 2, None).unwrap().prefix(10_000),
        Dataset::load("smtp3", 3, None).unwrap().prefix(10_000),
    ];
    let truths: Vec<Vec<bool>> = streams.iter().map(|d| d.labels.clone()).collect();
    let contamination: Vec<f64> = streams.iter().map(|d| d.contamination()).collect();

    let mut cfg = FseadConfig::fig7b();
    cfg.use_fpga = use_fpga;
    let mut fabric = Fabric::new(cfg, streams)?;

    println!("== phase 1: Fig 7(b) — three independent applications ==");
    for (id, rm) in fabric.assignments() {
        println!("  RP-{id}: {rm}");
    }
    let out = fabric.run()?;
    for (combo, stream) in [(1usize, 0usize), (2, 1), (3, 2)] {
        let (auc_s, _) = score_label_auc(&out.combo_scores[&combo], &truths[stream], contamination[stream]);
        println!("  app {combo}: AUC-S {auc_s:.4}  ({} samples)", out.combo_scores[&combo].len());
    }

    println!("\n== DFX: reconfigure all pblocks to Loda on stream 0 ==");
    let mut total_model_ms = 0.0;
    let mut total_actual_ms = 0.0;
    for id in 1..=7 {
        let rep = fabric.reconfigure(
            id,
            RmKind::Detector(DetectorKind::Loda),
            DetectorKind::Loda.pblock_r(),
            0,
        )?;
        println!(
            "  RP-{id}: {} -> {}  (DFX model {:.1} ms, swap here {:.2} ms)",
            rep.from, rep.to, rep.model_ms, rep.actual_ms
        );
        total_model_ms += rep.model_ms;
        total_actual_ms += rep.actual_ms;
    }
    fabric.set_combos(vec![
        ComboCfg { id: 1, method: "avg".into(), inputs: vec![1, 2, 3, 4], weights: vec![] },
        ComboCfg { id: 2, method: "avg".into(), inputs: vec![5, 6, 7], weights: vec![] },
    ])?;
    println!(
        "  total: modelled DFX downloads {total_model_ms:.0} ms, measured swaps {total_actual_ms:.1} ms"
    );

    println!("\n== phase 2: Fig 7(c) — 245-subdetector homogeneous Loda ensemble ==");
    let out = fabric.run()?;
    let n = out.combo_scores[&1].len();
    let mut combined = vec![0f32; n];
    // Host-side merge of the two combo stages (4+3 pblock weighting).
    for (c, (a, b)) in combined
        .iter_mut()
        .zip(out.combo_scores[&1].iter().zip(out.combo_scores[&2].iter()))
    {
        *c = (4.0 * a + 3.0 * b) / 7.0;
    }
    let (auc_s, auc_l) = score_label_auc(&combined, &truths[0], contamination[0]);
    println!("  cardio with 245 Loda sub-detectors: AUC-S {auc_s:.4}  AUC-L {auc_l:.4}");
    println!("  pass wall {:.1} ms, modelled FPGA {:.1} ms", out.wall_secs * 1e3, out.modeled_fpga_secs * 1e3);
    Ok(())
}
