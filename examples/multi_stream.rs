//! Multi-stream serving (paper Fig 7a): seven independent anomaly-detection
//! applications, one per pblock, each on its own DMA channel — the
//! configuration a monitoring deployment would use for seven sensors.
//!
//! Two of the sensors misbehave half-way through (an abrupt level shift in
//! every feature — the classic "sensor recalibrated itself" drift), and the
//! adaptive live-DFX controller is on: it watches each pblock's score
//! stream, and when the drift proxy trips it hot-swaps the drifting
//! pblock's detector from the configured pool while the other six streams
//! keep flowing. The ensemble reshapes itself mid-run.
//!
//! ```sh
//! cargo run --release --example multi_stream
//! ```

use anyhow::Result;
use fsead::config::{FseadConfig, PblockCfg, PoolEntry, RmKind};
use fsead::data::synth::{generate_profile, DatasetProfile};
use fsead::data::Dataset;
use fsead::detectors::DetectorKind;
use fsead::exp::score_label_auc;
use fsead::fabric::Fabric;

fn main() -> Result<()> {
    // Seven independent sensor streams with different characteristics.
    let mut streams: Vec<Dataset> = (0..7)
        .map(|i| {
            let p = DatasetProfile {
                name: "sensor",
                n: 8_000 + i * 1_000,
                d: 3,
                outliers: 80 + i * 20,
                clusters: 2 + (i % 3),
            };
            generate_profile(&p, 100 + i as u64)
        })
        .collect();
    // Sensors 2 and 5 drift abruptly half-way: every feature jumps by +6.
    for &s in &[2usize, 5] {
        let mid = streams[s].data.len() / 2;
        for v in streams[s].data[mid..].iter_mut() {
            *v += 6.0;
        }
    }

    let mut cfg = FseadConfig::default();
    cfg.use_fpga = std::path::Path::new("artifacts/manifest.txt").exists();
    // Alternate detector algorithms across the pblocks.
    let kinds = [DetectorKind::Loda, DetectorKind::RsHash, DetectorKind::XStream];
    for id in 1..=7usize {
        let kind = kinds[(id - 1) % 3];
        cfg.pblocks.push(PblockCfg {
            id,
            rm: RmKind::Detector(kind),
            r: kind.pblock_r(),
            stream: id - 1,
            lanes: 0,
        });
    }
    // Adaptive live DFX: watch every pblock's score stream; on drift, swap
    // the drifting pblock to the next pool detector while the fabric keeps
    // streaming (dark windows priced by the Table-13 model at the declared
    // stream rate; bypass policy keeps every stream sample-aligned).
    cfg.dfx.adaptive = true;
    cfg.dfx.window = 64;
    cfg.dfx.baseline = 256;
    cfg.dfx.threshold = 2.5;
    cfg.dfx.cooldown_flits = 8;
    cfg.dfx.samples_per_sec = 1_700.0;
    cfg.dfx.pool = vec![
        PoolEntry { kind: DetectorKind::Loda, r: 8 },
        PoolEntry { kind: DetectorKind::RsHash, r: 8 },
        PoolEntry { kind: DetectorKind::XStream, r: 8 },
    ];
    // Finer flits (~125-200 per stream) give the controller flit-level
    // resolution to act within the run.
    cfg.chunk = 64;

    let truths: Vec<Vec<bool>> = streams.iter().map(|d| d.labels.clone()).collect();
    let contaminations: Vec<f64> = streams.iter().map(|d| d.contamination()).collect();
    let mut fabric = Fabric::new(cfg, streams)?;
    let out = fabric.run()?;

    println!(
        "served 7 streams in {:.1} ms wall ({} switch flits, modelled FPGA {:.1} ms)",
        out.wall_secs * 1e3,
        out.switch_flits,
        out.modeled_fpga_secs * 1e3
    );
    for (id, rm) in fabric.assignments() {
        let scores = &out.pblock_scores[&id];
        let s = id - 1;
        let (auc_s, auc_l) = score_label_auc(scores, &truths[s], contaminations[s]);
        let report = &out.pblock_reports[&id];
        println!(
            "  RP-{id} [{rm:<14}] stream {s}: {} samples, AUC-S {auc_s:.4}, AUC-L {auc_l:.4}, busy {:.1} ms",
            scores.len(),
            report.busy_secs * 1e3
        );
    }
    println!(
        "adaptive live DFX: {} swap(s) issued, {} executed mid-run",
        out.adaptive_swaps_issued,
        out.swap_events.len()
    );
    for ev in &out.swap_events {
        println!("  {ev}");
    }
    if out.swap_events.is_empty() {
        println!("  (stream ended before the controller acted — rerun or raise n for a longer run)");
    }
    Ok(())
}
