//! Multi-stream serving (paper Fig 7a): seven independent anomaly-detection
//! applications, one per pblock, each on its own DMA channel — the
//! configuration a monitoring deployment would use for seven sensors.
//!
//! ```sh
//! cargo run --release --example multi_stream
//! ```

use anyhow::Result;
use fsead::config::{FseadConfig, PblockCfg, RmKind};
use fsead::data::synth::{generate_profile, DatasetProfile};
use fsead::data::Dataset;
use fsead::detectors::DetectorKind;
use fsead::exp::score_label_auc;
use fsead::fabric::Fabric;

fn main() -> Result<()> {
    // Seven independent sensor streams with different characteristics.
    let streams: Vec<Dataset> = (0..7)
        .map(|i| {
            let p = DatasetProfile {
                name: "sensor",
                n: 8_000 + i * 1_000,
                d: 3,
                outliers: 80 + i * 20,
                clusters: 2 + (i % 3),
            };
            generate_profile(&p, 100 + i as u64)
        })
        .collect();

    let mut cfg = FseadConfig::default();
    cfg.use_fpga = std::path::Path::new("artifacts/manifest.txt").exists();
    // Alternate detector algorithms across the pblocks.
    let kinds = [DetectorKind::Loda, DetectorKind::RsHash, DetectorKind::XStream];
    for id in 1..=7usize {
        let kind = kinds[(id - 1) % 3];
        cfg.pblocks.push(PblockCfg { id, rm: RmKind::Detector(kind), r: kind.pblock_r(), stream: id - 1 });
    }

    let truths: Vec<Vec<bool>> = streams.iter().map(|d| d.labels.clone()).collect();
    let contaminations: Vec<f64> = streams.iter().map(|d| d.contamination()).collect();
    let mut fabric = Fabric::new(cfg, streams)?;
    let out = fabric.run()?;

    println!(
        "served 7 streams in {:.1} ms wall ({} switch flits, modelled FPGA {:.1} ms)",
        out.wall_secs * 1e3,
        out.switch_flits,
        out.modeled_fpga_secs * 1e3
    );
    for (id, rm) in fabric.assignments() {
        let scores = &out.pblock_scores[&id];
        let s = id - 1;
        let (auc_s, auc_l) = score_label_auc(scores, &truths[s], contaminations[s]);
        let report = &out.pblock_reports[&id];
        println!(
            "  RP-{id} [{rm:<14}] stream {s}: {} samples, AUC-S {auc_s:.4}, AUC-L {auc_l:.4}, busy {:.1} ms",
            scores.len(),
            report.busy_secs * 1e3
        );
    }
    Ok(())
}
