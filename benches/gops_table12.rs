//! Bench: Table 12 — GOPS achieved by the CPU baseline and modelled for
//! fSEAD, from the Table 11 operation counts.

mod bench_util;
use bench_util::{cap, Bench};

use fsead::detectors::{DetectorKind, DetectorSpec};
use fsead::ensemble::run_threaded;
use fsead::exp::table11_12::params_for;
use fsead::exp::DATASETS;
use fsead::hw::opcount::{gops, op_count, paper_gops};
use fsead::hw::timing::FpgaTimingModel;

fn main() {
    let b = Bench::new("table12");
    let model = FpgaTimingModel::default();
    for kind in DetectorKind::ALL {
        for dataset in DATASETS {
            let ds = fsead::data::Dataset::load(dataset, 42, None).unwrap().prefix(cap());
            let p = params_for(kind, ds.n(), ds.d);
            let ops = op_count(kind, p);
            let spec = DetectorSpec::new(kind, ds.d, p.r as usize, 42);
            let t = b.run(&format!("{}/{dataset}", kind.as_str()), || {
                run_threaded(&spec, &ds, 4);
            });
            let (p_cpu, p_fsead) = paper_gops(kind, dataset).unwrap();
            println!(
                "  -> GOPS: cpu {:.2} | fsead-model {:.2} | paper {p_cpu:.2}/{p_fsead:.2}",
                gops(ops, t),
                gops(ops, model.exec_time_s(kind, ds.n(), ds.d)),
            );
        }
    }
}
