//! Bench: execution-mode throughput — sequential vs lock-step (paper §4.4)
//! vs the lock-free batched engine — for all three detectors on the Fig-11
//! workload shape (R=64, synthetic stream, 4 threads).
//!
//! Emits `BENCH_throughput.json` (samples/sec per detector × mode) to seed
//! the perf trajectory; the acceptance bar is batched ≥ 3× lock-step at
//! 4 threads.

mod bench_util;
use bench_util::{cap, Bench};

use fsead::data::synth::{generate_profile, DatasetProfile};
use fsead::detectors::{DetectorKind, DetectorSpec};
use fsead::ensemble::{run_batched, run_sequential, run_threaded};

const R: usize = 64;
const THREADS: usize = 4;

fn main() {
    let b = Bench::new("throughput_modes");
    let n = cap();
    let p = DatasetProfile { name: "modes", n, d: 8, outliers: n / 100, clusters: 3 };
    let ds = generate_profile(&p, 42);
    let n = ds.n();
    let mut rows: Vec<(&str, &str, f64)> = Vec::new();
    for kind in DetectorKind::ALL {
        let spec = DetectorSpec::new(kind, ds.d, R, 42);
        let t_seq = b.run(&format!("{}/sequential", kind.as_str()), || {
            run_sequential(&spec, &ds);
        });
        let t_lock = b.run(&format!("{}/lockstep/t{THREADS}", kind.as_str()), || {
            run_threaded(&spec, &ds, THREADS);
        });
        let t_bat = b.run(&format!("{}/batched/t{THREADS}", kind.as_str()), || {
            run_batched(&spec, &ds, THREADS);
        });
        println!(
            "  -> {}: batched {:.2}x vs lock-step, {:.2}x vs sequential ({:.0} samples/s)",
            kind.as_str(),
            t_lock / t_bat,
            t_seq / t_bat,
            n as f64 / t_bat
        );
        rows.push((kind.as_str(), "sequential", t_seq));
        rows.push((kind.as_str(), "lockstep", t_lock));
        rows.push((kind.as_str(), "batched", t_bat));
    }

    let mut json = String::from("{\n  \"bench\": \"throughput_modes\",\n");
    json.push_str(&format!(
        "  \"n\": {n},\n  \"d\": {},\n  \"r\": {R},\n  \"threads\": {THREADS},\n  \"rows\": [\n",
        ds.d
    ));
    for (i, (kind, mode, secs)) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"detector\": \"{kind}\", \"mode\": \"{mode}\", \"seconds\": {secs:.6}, \"samples_per_sec\": {:.1}}}{}\n",
            n as f64 / secs,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    json.push_str("  ]\n}\n");
    match std::fs::write("BENCH_throughput.json", &json) {
        Ok(()) => println!("wrote BENCH_throughput.json"),
        Err(e) => eprintln!("could not write BENCH_throughput.json: {e}"),
    }
}
