//! Shared mini-bench harness: criterion-style timing rows without criterion
//! (offline build). Each measurement warms up once, then reports
//! median/min/max over `iters` runs.

use std::time::Instant;

pub struct Bench {
    pub name: String,
    pub iters: usize,
}

impl Bench {
    pub fn new(name: &str) -> Bench {
        let iters = std::env::var("FSEAD_BENCH_ITERS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(3);
        Bench { name: name.to_string(), iters }
    }

    /// Time `f` and print a criterion-style row. Returns median seconds.
    pub fn run<F: FnMut()>(&self, case: &str, mut f: F) -> f64 {
        f(); // warm-up
        let mut times = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            f();
            times.push(t0.elapsed().as_secs_f64());
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let med = times[times.len() / 2];
        println!(
            "{}/{case}  time: [{} {} {}]",
            self.name,
            fmt(times[0]),
            fmt(med),
            fmt(times[times.len() - 1])
        );
        med
    }
}

pub fn fmt(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.3} s")
    } else if secs >= 1e-3 {
        format!("{:.3} ms", secs * 1e3)
    } else {
        format!("{:.1} µs", secs * 1e6)
    }
}

/// Sample cap for bench workloads (override with FSEAD_BENCH_SAMPLES).
#[allow(dead_code)] // not every bench binary streams a dataset
pub fn cap() -> usize {
    std::env::var("FSEAD_BENCH_SAMPLES").ok().and_then(|v| v.parse().ok()).unwrap_or(10_000)
}
