//! Bench: session lifecycle machinery — many-open/few-hot multiplexing
//! throughput (256 admitted sessions over 8 partitions), the
//! suspend→resume checkpoint round-trip rate, and the push→score latency
//! penalty of resuming an idle-evicted session versus a hot one.
//!
//! Emits `BENCH_sessions.json` for the perf trajectory; CI runs a smoke
//! pass on every PR and uploads it with the other BENCH artifacts.

#[allow(dead_code)] // only `cap` is used from the shared harness here
mod bench_util;
use bench_util::cap;

use fsead::config::{FseadConfig, PblockCfg, RmKind};
use fsead::data::synth::{generate_profile, DatasetProfile};
use fsead::data::Dataset;
use fsead::detectors::DetectorKind;
use fsead::fabric::server::{FabricServer, SessionSpec};
use std::time::{Duration, Instant};

const CHUNK: usize = 64;
const D: usize = 3;
const PARTITIONS: usize = 8;
const SESSIONS: usize = 256;
const CYCLES: usize = 32;
const LATENCY_PUSHES: usize = 24;

fn topology(partitions: usize) -> FseadConfig {
    let mut cfg = FseadConfig { use_fpga: false, chunk: CHUNK, ..FseadConfig::default() };
    // Small hyper-parameters: the bench times the lifecycle machinery
    // (admission, snapshot switching, parking), not the detectors.
    cfg.hyper.window = 16;
    cfg.hyper.bins = 8;
    cfg.hyper.modulus = 32;
    cfg.hyper.k = 4;
    for id in 1..=partitions {
        cfg.pblocks.push(PblockCfg {
            id,
            rm: RmKind::Detector(DetectorKind::Loda),
            r: 2,
            stream: 0,
            lanes: 0,
        });
    }
    cfg
}

fn dataset() -> Dataset {
    let p = DatasetProfile { name: "lifecycle", n: CHUNK * 8, d: D, outliers: 24, clusters: 2 };
    generate_profile(&p, 11)
}

fn median(xs: &mut [f64]) -> f64 {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    xs[xs.len() / 2]
}

/// 256 sessions admitted onto 8 partitions (32 per slot): every partition
/// round-robins its tenants, swapping RM state per switch. Reports
/// sessions/sec and samples/sec across open → interleaved pushes → close.
fn bench_mux_fanout(rounds: usize, ds: &Dataset) -> (f64, f64, f64, usize) {
    let mut cfg = topology(PARTITIONS);
    cfg.server.sessions_per_partition = SESSIONS / PARTITIONS;
    let server = FabricServer::start(cfg.clone()).expect("server start");
    let chunk = &ds.data[..CHUNK * D];
    let t0 = Instant::now();
    let mut sessions: Vec<_> = (0..SESSIONS)
        .map(|_| server.open(SessionSpec::for_dataset(ds, cfg.hyper.window)).expect("open"))
        .collect();
    for _ in 0..rounds {
        for s in sessions.iter_mut() {
            s.push(chunk).expect("push");
        }
    }
    let mut samples = 0u64;
    for s in sessions.drain(..) {
        samples += s.close().expect("close").samples;
    }
    let wall = t0.elapsed().as_secs_f64();
    server.shutdown().expect("shutdown");
    (wall, SESSIONS as f64 / wall, samples as f64 / wall, rounds)
}

/// Suspend→resume cycle rate on a dedicated partition: each cycle encodes
/// a snapshot into a ticket, releases the slot, re-admits and restores.
fn bench_suspend_resume(ds: &Dataset) -> (f64, f64) {
    let cfg = topology(1);
    let server = FabricServer::start(cfg.clone()).expect("server start");
    let chunk = &ds.data[..CHUNK * D];
    let mut session =
        server.open(SessionSpec::for_dataset(ds, cfg.hyper.window)).expect("open");
    let t0 = Instant::now();
    for _ in 0..CYCLES {
        session.push(chunk).expect("push");
        let (ticket, _scores) = session.suspend().expect("suspend");
        session = server.resume(ticket).expect("resume");
    }
    let wall = t0.elapsed().as_secs_f64();
    session.close().expect("close");
    server.shutdown().expect("shutdown");
    (wall, CYCLES as f64 / wall)
}

/// Push→score round-trip latency: hot (resident RM) versus after an idle
/// sweep parked the session (claim + snapshot restore on the next push).
fn bench_evict_resume(ds: &Dataset) -> (f64, f64) {
    let mut cfg = topology(1);
    cfg.server.idle_evict_flits = 2;
    let server = FabricServer::start(cfg.clone()).expect("server start");
    let chunk = &ds.data[..CHUNK * D];
    let mut session =
        server.open(SessionSpec::for_dataset(ds, cfg.hyper.window)).expect("open");
    let mut probe = |s: &mut fsead::fabric::Session| {
        let t0 = Instant::now();
        s.push(chunk).expect("push");
        s.recv_scores().expect("scores");
        t0.elapsed().as_secs_f64() * 1e3
    };
    let mut hot = Vec::with_capacity(LATENCY_PUSHES);
    for _ in 0..LATENCY_PUSHES {
        hot.push(probe(&mut session));
    }
    let mut evicted = Vec::with_capacity(LATENCY_PUSHES);
    for _ in 0..LATENCY_PUSHES {
        // Long enough for the idle sweep (sub-millisecond ticks) to park
        // the session, so the next push pays claim + restore.
        std::thread::sleep(Duration::from_millis(25));
        evicted.push(probe(&mut session));
    }
    session.close().expect("close");
    server.shutdown().expect("shutdown");
    (median(&mut hot), median(&mut evicted))
}

fn main() {
    let rounds: usize = (cap() / (SESSIONS * CHUNK)).clamp(2, 16);
    let ds = dataset();

    let (mux_wall, sessions_per_sec, samples_per_sec, rounds) = bench_mux_fanout(rounds, &ds);
    println!(
        "session_lifecycle/mux_fanout  {SESSIONS} sessions on {PARTITIONS} partitions, \
         {rounds} rounds in {mux_wall:.3} s — {sessions_per_sec:.1} sessions/s, \
         {samples_per_sec:.0} samples/s"
    );
    let (sr_wall, cycles_per_sec) = bench_suspend_resume(&ds);
    println!(
        "session_lifecycle/suspend_resume  {CYCLES} checkpoint round-trips in {sr_wall:.3} s \
         — {cycles_per_sec:.1} cycles/s"
    );
    let (hot_p50_ms, evicted_p50_ms) = bench_evict_resume(&ds);
    println!(
        "session_lifecycle/evict_resume  push→score p50: hot {hot_p50_ms:.3} ms, \
         after idle eviction {evicted_p50_ms:.3} ms"
    );

    let json = format!(
        "{{\n  \"bench\": \"session_lifecycle\",\n  \"partitions\": {PARTITIONS},\n  \
         \"chunk\": {CHUNK},\n  \"rows\": [\n    \
         {{\"case\": \"mux_fanout\", \"sessions\": {SESSIONS}, \"rounds\": {rounds}, \
         \"wall_secs\": {mux_wall:.6}, \"sessions_per_sec\": {sessions_per_sec:.3}, \
         \"samples_per_sec\": {samples_per_sec:.1}}},\n    \
         {{\"case\": \"suspend_resume\", \"cycles\": {CYCLES}, \"wall_secs\": {sr_wall:.6}, \
         \"cycles_per_sec\": {cycles_per_sec:.3}}},\n    \
         {{\"case\": \"evict_resume\", \"pushes\": {LATENCY_PUSHES}, \
         \"hot_p50_ms\": {hot_p50_ms:.4}, \"evicted_p50_ms\": {evicted_p50_ms:.4}}}\n  ]\n}}\n"
    );
    match std::fs::write("BENCH_sessions.json", &json) {
        Ok(()) => println!("wrote BENCH_sessions.json"),
        Err(e) => eprintln!("could not write BENCH_sessions.json: {e}"),
    }
}
