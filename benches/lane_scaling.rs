//! Bench: multi-lane pblock throughput — samples/sec for one detector
//! partition at lanes ∈ {1, 2, 4}, in both drain modes, for all three
//! detectors (paper §4 / Fig 9: "multiple instances can be placed within a
//! pblock to improve performance").
//!
//! The topology is a single pblock with R = 16 sub-detectors on one
//! synthetic stream, so the measurement isolates what lanes buy: the
//! partition's ensemble scored by 1, 2 or 4 resident lane workers instead
//! of one service thread. On a single-core host the lane counts converge —
//! the bench still gates parity and records the numbers.
//!
//! **Parity gate** (runs before any timing): for every detector × mode,
//! `lanes > 1` scores must stay within 1e-5 of the `lanes = 1` stream —
//! the established partition tolerance (lanes only reorder the f32
//! ensemble-mean summation).
//!
//! Emits `BENCH_lanes.json` (seconds + samples/sec per detector × mode ×
//! lane count, plus lane-4 speed-ups) for the perf trajectory; the
//! acceptance bar on multi-core hosts is lanes=4 ≥ 2× lanes=1 samples/sec
//! on this workload.

mod bench_util;
use bench_util::{cap, Bench};

use fsead::config::{FseadConfig, PblockCfg, RmKind};
use fsead::data::synth::{generate_profile, DatasetProfile};
use fsead::detectors::DetectorKind;
use fsead::ensemble::ExecMode;
use fsead::fabric::Fabric;

/// Sub-detectors in the partition (divisible by every lane count).
const R: usize = 16;
const LANES: [usize; 3] = [1, 2, 4];

fn topology(kind: DetectorKind, exec: ExecMode, lanes: usize) -> FseadConfig {
    let mut cfg = FseadConfig::default();
    cfg.use_fpga = false;
    cfg.exec = exec;
    cfg.pblocks.push(PblockCfg { id: 1, rm: RmKind::Detector(kind), r: R, stream: 0, lanes });
    cfg
}

fn main() {
    let bench = Bench::new("lane_scaling");
    let n = cap();
    let p = DatasetProfile { name: "lanes", n, d: 8, outliers: n / 100, clusters: 3 };
    let ds = generate_profile(&p, 42);
    let n = ds.n();

    let mut rows: Vec<(&str, &str, usize, f64)> = Vec::new();
    let mut speedups: Vec<(String, f64)> = Vec::new();
    for kind in DetectorKind::ALL {
        for mode in ExecMode::ALL {
            let mut base_scores: Vec<f32> = Vec::new();
            let mut secs = Vec::new();
            for lanes in LANES {
                let mut fabric =
                    Fabric::new(topology(kind, mode, lanes), vec![ds.clone()]).unwrap();
                // Parity gate before timing: lanes must not change scores
                // beyond the 1e-5 partition tolerance.
                let scores = fabric.run().unwrap().pblock_scores[&1].clone();
                if lanes == 1 {
                    base_scores = scores;
                } else {
                    assert_eq!(scores.len(), base_scores.len());
                    for (i, (a, b)) in base_scores.iter().zip(&scores).enumerate() {
                        let tol = 1e-5 * a.abs().max(b.abs()).max(1.0);
                        assert!(
                            (a - b).abs() <= tol,
                            "parity gate: {}/{}/lanes{} sample {i}: {a} vs {b}",
                            kind.as_str(),
                            mode.as_str(),
                            lanes
                        );
                    }
                }
                let t = bench.run(
                    &format!("{}/{}/lanes{}", kind.as_str(), mode.as_str(), lanes),
                    || {
                        fabric.reset_all().unwrap();
                        let out = fabric.run().unwrap();
                        assert_eq!(out.pblock_scores[&1].len(), n);
                    },
                );
                secs.push(t);
                rows.push((kind.as_str(), mode.as_str(), lanes, t));
            }
            let sp = secs[0] / secs[LANES.len() - 1];
            println!(
                "  -> {}/{}: lanes=4 {:.2}x vs lanes=1 ({:.0} samples/s)",
                kind.as_str(),
                mode.as_str(),
                sp,
                n as f64 / secs[LANES.len() - 1]
            );
            speedups.push((format!("{}/{}", kind.as_str(), mode.as_str()), sp));
        }
    }

    let mut json = String::from("{\n  \"bench\": \"lane_scaling\",\n");
    json.push_str(&format!("  \"n\": {n},\n  \"d\": {},\n  \"r\": {R},\n  \"rows\": [\n", ds.d));
    for (i, (kind, mode, lanes, secs)) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"detector\": \"{kind}\", \"mode\": \"{mode}\", \"lanes\": {lanes}, \
             \"seconds\": {secs:.6}, \"samples_per_sec\": {:.1}}}{}\n",
            n as f64 / secs,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    json.push_str("  ],\n  \"lane4_speedup\": {\n");
    for (i, (key, sp)) in speedups.iter().enumerate() {
        json.push_str(&format!(
            "    \"{key}\": {sp:.3}{}\n",
            if i + 1 < speedups.len() { "," } else { "" }
        ));
    }
    json.push_str("  }\n}\n");
    match std::fs::write("BENCH_lanes.json", &json) {
        Ok(()) => println!("wrote BENCH_lanes.json"),
        Err(e) => eprintln!("could not write BENCH_lanes.json: {e}"),
    }
}
