//! Bench: Figures 12–14 — CPU execution time vs ensemble size (linear)
//! against the flat FPGA model.

mod bench_util;
use bench_util::{cap, fmt, Bench};

use fsead::detectors::{DetectorKind, DetectorSpec};
use fsead::ensemble::run_sequential;
use fsead::hw::timing::FpgaTimingModel;

fn main() {
    let b = Bench::new("figs12_14");
    let ds = fsead::data::Dataset::load("shuttle", 42, None).unwrap().prefix(cap());
    let model = FpgaTimingModel::default();
    for kind in DetectorKind::ALL {
        let fpga = model.exec_time_s(kind, ds.n(), ds.d);
        for mult in [1usize, 2, 4, 7] {
            let r = mult * kind.pblock_r();
            let spec = DetectorSpec::new(kind, ds.d, r, 42);
            let t = b.run(&format!("{}/R={r}", kind.as_str()), || {
                run_sequential(&spec, &ds);
            });
            println!("  -> cpu {} vs fpga-model {} (flat in R)", fmt(t), fmt(fpga));
        }
    }
}
