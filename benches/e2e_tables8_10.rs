//! Bench: Tables 8–10 end-to-end — CPU baseline vs PJRT fabric per
//! detector per dataset (capped streams; FSEAD_BENCH_SAMPLES to change).

mod bench_util;
use bench_util::{cap, fmt, Bench};

use fsead::config::{FseadConfig, PblockCfg, RmKind};
use fsead::detectors::{DetectorKind, DetectorSpec};
use fsead::ensemble::run_threaded;
use fsead::exp::DATASETS;
use fsead::fabric::Fabric;
use fsead::hw::timing::FpgaTimingModel;

fn main() {
    let b = Bench::new("tables8_10");
    let have_artifacts = std::path::Path::new("artifacts/manifest.txt").exists();
    let model = FpgaTimingModel::default();
    for kind in DetectorKind::ALL {
        for dataset in DATASETS {
            let ds = fsead::data::Dataset::load(dataset, 42, None).unwrap().prefix(cap());
            // CPU baseline (paper's 4-thread GCC analogue).
            let r = 7 * kind.pblock_r();
            let spec = DetectorSpec::new(kind, ds.d, r, 42);
            let cpu = b.run(&format!("cpu4/{}/{dataset}", kind.as_str()), || {
                let s = run_threaded(&spec, &ds, 4);
                assert_eq!(s.len(), ds.n());
            });
            // PJRT fabric (7 pblocks), if artifacts are present.
            let mut sim = f64::NAN;
            if have_artifacts {
                let mut cfg = FseadConfig::default();
                cfg.chunk = 256;
                for id in 1..=7usize {
                    cfg.pblocks.push(PblockCfg {
                        id,
                        rm: RmKind::Detector(kind),
                        r: kind.pblock_r(),
                        stream: 0,
                        lanes: 0,
                    });
                }
                let mut fabric = Fabric::new(cfg, vec![ds.clone()]).unwrap();
                sim = b.run(&format!("pjrt/{}/{dataset}", kind.as_str()), || {
                    fabric.reset_all().unwrap();
                    fabric.run().unwrap();
                });
            }
            let fpga = model.exec_time_s(kind, ds.n(), ds.d);
            println!(
                "  -> {}/{dataset}: cpu {} | fpga-model {} | pjrt-sim {} | speedup(model) {:.2}x",
                kind.as_str(),
                fmt(cpu),
                fmt(fpga),
                if sim.is_nan() { "n/a".into() } else { fmt(sim) },
                cpu / fpga
            );
        }
    }
}
