//! Bench: fabric data-plane throughput — the per-flit (lock-step) service
//! loop vs zero-copy burst servicing — on the 4-pblock synthetic topology
//! (4 Loda pblocks on one shared stream; once routed direct to host, once
//! joined by an averaging combo).
//!
//! The flit granularity is deliberately fine (`CHUNK = 4` samples per
//! transfer) so the measurement isolates what the burst data plane
//! amortises: per-transfer channel hops, per-flit RM invocations and
//! per-flit allocation. At the artifact chunk size (256) both paths are
//! compute-bound and converge. Scores are asserted bit-identical between
//! the two modes before timing starts.
//!
//! Emits `BENCH_fabric.json` (seconds + samples/sec per topology × mode,
//! plus the burst speed-up) for the perf trajectory; the acceptance bar is
//! burst ≥ 2× per-flit samples/sec on this topology.

mod bench_util;
use bench_util::{cap, Bench};

use fsead::config::{ComboCfg, FseadConfig, PblockCfg, RmKind};
use fsead::data::synth::{generate_profile, DatasetProfile};
use fsead::detectors::DetectorKind;
use fsead::ensemble::ExecMode;
use fsead::fabric::Fabric;

/// Samples per flit for the timed runs (fine-grained on purpose, see above).
const CHUNK: usize = 4;

fn topology(exec: ExecMode, combo: bool, chunk: usize) -> FseadConfig {
    let mut cfg = FseadConfig::default();
    cfg.use_fpga = false;
    cfg.exec = exec;
    cfg.chunk = chunk;
    for id in 1..=4usize {
        cfg.pblocks.push(PblockCfg {
            id,
            rm: RmKind::Detector(DetectorKind::Loda),
            r: 2,
            stream: 0,
            lanes: 0,
        });
    }
    if combo {
        cfg.combos.push(ComboCfg {
            id: 1,
            method: "avg".into(),
            inputs: vec![1, 2, 3, 4],
            weights: vec![],
        });
    }
    cfg
}

fn main() {
    let bench = Bench::new("fabric_pipeline");
    let n = cap();
    let p = DatasetProfile { name: "fabric", n, d: 4, outliers: n / 100, clusters: 3 };
    let ds = generate_profile(&p, 42);
    let n = ds.n();

    // Parity gate before timing: the burst path must reproduce the
    // per-flit path bit-for-bit on CPU RMs.
    {
        let mut a = Fabric::new(topology(ExecMode::LockStep, true, 64), vec![ds.clone()]).unwrap();
        let mut b = Fabric::new(topology(ExecMode::Batched, true, 64), vec![ds.clone()]).unwrap();
        let oa = a.run().unwrap();
        let ob = b.run().unwrap();
        assert_eq!(
            oa.combo_scores[&1], ob.combo_scores[&1],
            "burst scores drifted from the per-flit path"
        );
        println!("parity: burst == per-flit on {n} samples (bit-identical)");
    }

    let mut rows: Vec<(&str, &str, f64)> = Vec::new();
    let mut speedups: Vec<(&str, f64)> = Vec::new();
    for (topo, combo) in [("direct4", false), ("combo4", true)] {
        let mut secs = [0f64; 2];
        for (mi, mode) in ExecMode::ALL.iter().enumerate() {
            let mut fabric =
                Fabric::new(topology(*mode, combo, CHUNK), vec![ds.clone()]).unwrap();
            let t = bench.run(&format!("{topo}/{}", mode.as_str()), || {
                fabric.reset_all().unwrap();
                let out = fabric.run().unwrap();
                assert!(out.switch_flits > 0);
            });
            secs[mi] = t;
            rows.push((topo, mode.as_str(), t));
        }
        let sp = secs[0] / secs[1]; // lock-step seconds / batched seconds
        println!(
            "  -> {topo}: burst {:.2}x vs per-flit ({:.0} samples/s burst, {:.0} per-flit)",
            sp,
            n as f64 / secs[1],
            n as f64 / secs[0]
        );
        speedups.push((topo, sp));
    }

    let mut json = String::from("{\n  \"bench\": \"fabric_pipeline\",\n");
    json.push_str(&format!(
        "  \"n\": {n},\n  \"d\": {},\n  \"chunk\": {CHUNK},\n  \"pblocks\": 4,\n  \"rows\": [\n",
        ds.d
    ));
    for (i, (topo, mode, secs)) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"topology\": \"{topo}\", \"mode\": \"{mode}\", \"seconds\": {secs:.6}, \"samples_per_sec\": {:.1}}}{}\n",
            n as f64 / secs,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    json.push_str("  ],\n  \"burst_speedup\": {\n");
    for (i, (topo, sp)) in speedups.iter().enumerate() {
        json.push_str(&format!(
            "    \"{topo}\": {sp:.3}{}\n",
            if i + 1 < speedups.len() { "," } else { "" }
        ));
    }
    json.push_str("  }\n}\n");
    match std::fs::write("BENCH_fabric.json", &json) {
        Ok(()) => println!("wrote BENCH_fabric.json"),
        Err(e) => eprintln!("could not write BENCH_fabric.json: {e}"),
    }
}
