//! Bench: fault-recovery cost — the price of the supervisor's rung-1
//! ladder, measured on a streaming fabric (1 Loda pblock, chunk 16). Each
//! timed faulted pass injects scripted state corruption at three points in
//! the stream; every corruption is screened at the output, reloaded
//! through the DFX stage path and resumed from the latest checkpoint. A
//! clean pass of the same workload (campaign disabled) gives the baseline,
//! so the delta prices detection + screen + reload + restore end to end.
//!
//! Emits `BENCH_faults.json`: per-mode wall times clean vs faulted, the
//! reload count, how many reloads resumed from a checkpoint, the mean
//! in-supervisor recovery latency and the samples lost to screening + dark
//! windows (gates: every injection recovers at rung 1, every reload is a
//! checkpoint resume, nothing quarantines, score framing is preserved).

mod bench_util;
use bench_util::{cap, Bench};

use fsead::config::{DarkPolicy, FseadConfig, InjectSpec, PblockCfg, RmKind};
use fsead::data::synth::{generate_profile, DatasetProfile};
use fsead::detectors::DetectorKind;
use fsead::ensemble::ExecMode;
use fsead::fabric::Fabric;

const CHUNK: usize = 16;

fn topology(exec: ExecMode, inject_at: &[u64]) -> FseadConfig {
    let mut cfg = FseadConfig::default();
    cfg.use_fpga = false;
    cfg.exec = exec;
    cfg.chunk = CHUNK;
    cfg.hyper.window = 16;
    cfg.hyper.bins = 8;
    cfg.hyper.modulus = 32;
    cfg.hyper.k = 4;
    cfg.dfx.policy = DarkPolicy::Bypass;
    cfg.pblocks.push(PblockCfg {
        id: 1,
        rm: RmKind::Detector(DetectorKind::Loda),
        r: 4,
        stream: 0,
        lanes: 0,
    });
    if !inject_at.is_empty() {
        cfg.faults.enabled = true;
        cfg.faults.checkpoint_every_flits = 8;
        cfg.faults.dark_flits = Some(1);
        cfg.faults.max_reloads = 32;
        cfg.faults.backoff_ms = 0;
        // Generous margins so a loaded CI box never times the screen wait
        // out or trips the watchdog on a slow flit.
        cfg.faults.reload_wait_ms = 5_000;
        cfg.faults.stall_timeout_ms = 2_000;
        for (i, &at) in inject_at.iter().enumerate() {
            cfg.faults.injections.push(InjectSpec {
                id: format!("seu{i}"),
                pblock: 1,
                at_flit: at,
                kind: "state_corrupt".into(),
                lane: 0,
                ms: 0,
            });
        }
    }
    cfg
}

struct Row {
    mode: &'static str,
    secs_clean: f64,
    secs_faulted: f64,
    reloads: usize,
    checkpoint_restores: usize,
    mean_recovery_us: f64,
    samples_zeroed: u64,
}

fn main() {
    let bench = Bench::new("fault_recovery");
    let n = cap();
    let p = DatasetProfile { name: "faults", n, d: 4, outliers: n / 100, clusters: 3 };
    let ds = generate_profile(&p, 42);
    let n = ds.n();
    let total_flits = n.div_ceil(CHUNK) as u64;
    // Three corruption points spread through the stream, all past the first
    // checkpoint so every reload can resume instead of cold-starting.
    assert!(total_flits >= 64, "FSEAD_BENCH_SAMPLES too small for the fault campaign");
    let inject_at: Vec<u64> = [4u64, 8, 12].iter().map(|q| total_flits * q / 16).collect();
    let n_inj = inject_at.len();

    let mut rows: Vec<Row> = Vec::new();
    for mode in ExecMode::ALL {
        // Baseline: same workload, fault campaign disabled.
        let mut clean = Fabric::new(topology(mode, &[]), vec![ds.clone()]).unwrap();
        let secs_clean = bench.run(&format!("clean/{}", mode.as_str()), || {
            clean.reset_all().unwrap();
            let out = clean.run().unwrap();
            assert!(out.fault_events.is_empty());
        });

        // Faulted: the scripted campaign re-arms on every pass; each
        // corruption must end in a checkpoint-resumed rung-1 reload.
        let mut faulty = Fabric::new(topology(mode, &inject_at), vec![ds.clone()]).unwrap();
        let mut last = None;
        let secs_faulted = bench.run(&format!("faulted/{}", mode.as_str()), || {
            faulty.reset_all().unwrap();
            let out = faulty.run().unwrap();
            assert_eq!(out.pblock_scores[&1].len(), n, "score framing must survive faults");
            last = Some((out.fault_events.clone(), out.swap_events.clone()));
        });
        let (events, swaps) = last.expect("at least one timed pass");

        let count = |a: &str| events.iter().filter(|e| e.action == a).count();
        assert_eq!(count("injected"), n_inj, "every scripted fault fires");
        assert_eq!(count("nonfinite_detected"), n_inj, "every corruption is screened");
        assert_eq!(count("reloaded"), n_inj, "every corruption recovers at rung 1");
        assert_eq!(count("quarantined"), 0, "nothing escalates to rung 2");
        let reloaded: Vec<_> = events.iter().filter(|e| e.action == "reloaded").collect();
        let checkpoint_restores =
            reloaded.iter().filter(|e| e.checkpoint_flit.is_some()).count();
        assert_eq!(checkpoint_restores, n_inj, "every reload resumes from a checkpoint");
        let mean_recovery_us = reloaded.iter().map(|e| e.latency_us as f64).sum::<f64>()
            / reloaded.len().max(1) as f64;
        // Lost coverage: the screened (zeroed) corrupt flits plus the dark
        // window each reload charges, in samples.
        let dark_lost: u64 = swaps.iter().map(|s| s.bypassed + s.dropped).sum();
        let samples_zeroed = (n_inj as u64 + dark_lost) * CHUNK as u64;

        println!(
            "  -> {}: faulted pass {:.1} ms vs {:.1} ms clean; {} reloads ({} from \
             checkpoint), mean recovery {:.0} µs, {} samples zeroed",
            mode.as_str(),
            secs_faulted * 1e3,
            secs_clean * 1e3,
            reloaded.len(),
            checkpoint_restores,
            mean_recovery_us,
            samples_zeroed
        );
        rows.push(Row {
            mode: mode.as_str(),
            secs_clean,
            secs_faulted,
            reloads: reloaded.len(),
            checkpoint_restores,
            mean_recovery_us,
            samples_zeroed,
        });
    }

    let mut json = String::from("{\n  \"bench\": \"fault_recovery\",\n");
    json.push_str(&format!(
        "  \"n\": {n},\n  \"chunk\": {CHUNK},\n  \"injections\": {n_inj},\n  \"rows\": [\n"
    ));
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"mode\": \"{}\", \"seconds_clean\": {:.6}, \"seconds_faulted\": {:.6}, \
             \"reloads\": {}, \"checkpoint_restores\": {}, \"mean_recovery_us\": {:.1}, \
             \"samples_zeroed\": {}}}{}\n",
            r.mode,
            r.secs_clean,
            r.secs_faulted,
            r.reloads,
            r.checkpoint_restores,
            r.mean_recovery_us,
            r.samples_zeroed,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    json.push_str("  ]\n}\n");
    match std::fs::write("BENCH_faults.json", &json) {
        Ok(()) => println!("wrote BENCH_faults.json"),
        Err(e) => eprintln!("could not write BENCH_faults.json: {e}"),
    }
}
