//! Bench: the fault-tolerant session router — 64 concurrent loopback
//! clients through 1 router onto 4 `fsead net` workers, in both execution
//! modes, with one worker killed mid-run. Reports sessions/sec, push
//! round-trip p50/p99, the p99 client-visible pause of a re-shard (the
//! push whose reply carried a `rerouted` notice), and the fleet recovery
//! time from kill to the first successful re-admission on a survivor.
//!
//! The killed worker sits behind an in-process TCP proxy; severing the
//! proxy is, from the router's side, `kill -9` of the worker — every live
//! byte is gone and new connects are refused — while the bench keeps a
//! clean handle for teardown.
//!
//! Emits `BENCH_router.json`; CI runs a smoke pass on every PR, validates
//! the JSON and uploads it with the other BENCH artifacts.

#[allow(dead_code)] // only `cap` is used from the shared harness here
mod bench_util;
use bench_util::cap;

use std::io::{Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use fsead::config::{FseadConfig, PblockCfg, RmKind, RouterCfg};
use fsead::data::synth::{generate_profile, DatasetProfile};
use fsead::detectors::DetectorKind;
use fsead::ensemble::ExecMode;
use fsead::fabric::net::{NetServer, STATUS_REROUTED};
use fsead::fabric::net_client::NetClient;
use fsead::fabric::router::Router;
use fsead::fabric::server::FabricServer;

const WORKERS: usize = 4;
const CLIENTS: usize = 64;
const CHUNK: usize = 64;
const CHECKPOINT_PUSHES: u64 = 4;

fn worker_cfg(exec: ExecMode, base: u64) -> FseadConfig {
    let mut cfg = FseadConfig { use_fpga: false, exec, chunk: CHUNK, ..FseadConfig::default() };
    // Survivors absorb the dead worker's whole shard — admission head-room
    // for every session landing on one worker must exist.
    cfg.server.sessions_per_partition = CLIENTS + 8;
    cfg.server.session_id_base = base;
    cfg.pblocks.push(PblockCfg {
        id: 1,
        rm: RmKind::Detector(DetectorKind::Loda),
        r: 2,
        stream: 0,
        lanes: 0,
    });
    cfg
}

fn percentile_ms(sorted_secs: &[f64], p: f64) -> f64 {
    if sorted_secs.is_empty() {
        return 0.0;
    }
    let idx = ((sorted_secs.len() - 1) as f64 * p).round() as usize;
    sorted_secs[idx] * 1e3
}

/// Killable TCP pass-through (see `tests/router_resilience.rs`).
struct Proxy {
    addr: String,
    stop: Arc<AtomicBool>,
    conns: Arc<Mutex<Vec<TcpStream>>>,
    accept: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl Proxy {
    fn start(upstream: String) -> Proxy {
        let listener = TcpListener::bind("127.0.0.1:0").expect("proxy bind");
        let addr = listener.local_addr().expect("proxy addr").to_string();
        let stop = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<TcpStream>>> = Arc::new(Mutex::new(Vec::new()));
        let stop2 = Arc::clone(&stop);
        let conns2 = Arc::clone(&conns);
        let accept = std::thread::spawn(move || {
            for inbound in listener.incoming() {
                if stop2.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(down) = inbound else { continue };
                let Ok(up) = TcpStream::connect(&upstream) else { continue };
                let down2 = down.try_clone().expect("clone");
                let up2 = up.try_clone().expect("clone");
                {
                    let mut held = conns2.lock().unwrap();
                    held.push(down.try_clone().expect("clone"));
                    held.push(up.try_clone().expect("clone"));
                }
                std::thread::spawn(move || pump(down, up2));
                std::thread::spawn(move || pump(up, down2));
            }
        });
        Proxy { addr, stop, conns, accept: Mutex::new(Some(accept)) }
    }

    fn kill(&self) {
        self.stop.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(&self.addr);
        for s in self.conns.lock().unwrap().drain(..) {
            let _ = s.shutdown(Shutdown::Both);
        }
        if let Some(h) = self.accept.lock().unwrap().take() {
            let _ = h.join();
        }
    }
}

impl Drop for Proxy {
    fn drop(&mut self) {
        self.kill();
    }
}

fn pump(mut from: TcpStream, mut to: TcpStream) {
    let mut buf = [0u8; 4096];
    loop {
        match from.read(&mut buf) {
            Ok(0) | Err(_) => break,
            Ok(n) => {
                if to.write_all(&buf[..n]).is_err() {
                    break;
                }
            }
        }
    }
    let _ = to.shutdown(Shutdown::Both);
}

struct Row {
    mode: &'static str,
    sessions: u64,
    samples: u64,
    wall_secs: f64,
    latencies: Vec<f64>,
    reshard_pauses: Vec<f64>,
    recovery_secs: Option<f64>,
    rerouted: u64,
    lost: u64,
}

fn main() {
    let rounds: usize =
        std::env::var("FSEAD_BENCH_ITERS").ok().and_then(|v| v.parse().ok()).unwrap_or(1);
    let samples = (cap() / CLIENTS).max(CHUNK * 6);
    let pushes_per_session = samples.div_ceil(CHUNK);
    let total_pushes = (CLIENTS * rounds * pushes_per_session) as u64;
    let mut rows: Vec<Row> = Vec::new();
    for mode in ExecMode::ALL {
        let mut workers = Vec::new();
        for i in 0..WORKERS {
            let cfg = worker_cfg(mode, ((i + 1) as u64) << 32);
            let server = Arc::new(FabricServer::start(cfg).expect("worker start"));
            let net = NetServer::start_with_limit("127.0.0.1:0", Arc::clone(&server), CLIENTS + 8)
                .expect("net start");
            workers.push((server, net));
        }
        // Worker 0 is the one that dies: the router only ever sees its
        // proxied address.
        let proxy = Proxy::start(workers[0].1.addr().to_string());
        let mut addrs = vec![proxy.addr.clone()];
        addrs.extend(workers.iter().skip(1).map(|(_, net)| net.addr().to_string()));
        let router = Router::start(&RouterCfg {
            enabled: true,
            addr: "127.0.0.1:0".into(),
            workers: addrs,
            max_connections: CLIENTS + 8,
            heartbeat_ms: 50,
            max_failures: 2,
            checkpoint_pushes: CHECKPOINT_PUSHES,
            connect_timeout_ms: 1_000,
            io_timeout_ms: 0,
            retry_deadline_ms: 10_000,
            backoff_base_ms: 5,
            ..RouterCfg::default()
        })
        .expect("router start");
        let addr = router.addr().to_string();
        let window = worker_cfg(mode, 0).hyper.window;

        let pushed = AtomicU64::new(0);
        let t0 = Instant::now();
        let mut all_latencies: Vec<f64> = Vec::new();
        let mut all_pauses: Vec<f64> = Vec::new();
        let mut sessions = 0u64;
        let mut total_samples = 0u64;
        let mut recovery_secs: Option<f64> = None;
        std::thread::scope(|scope| {
            let mut handles = Vec::new();
            for client in 0..CLIENTS {
                let addr = &addr;
                let pushed = &pushed;
                handles.push(scope.spawn(move || -> (u64, u64, Vec<f64>, Vec<f64>) {
                    let mut latencies = Vec::new();
                    let mut pauses = Vec::new();
                    let mut done = 0u64;
                    let mut scored = 0u64;
                    for round in 0..rounds {
                        let profile = DatasetProfile {
                            name: "router",
                            n: samples,
                            d: 3,
                            outliers: samples / 50,
                            clusters: 2,
                        };
                        let ds = generate_profile(&profile, (client * 131 + round) as u64 + 1);
                        let mut c = NetClient::connect(addr).expect("connect");
                        c.open(ds.d, Some(1), ds.warmup(window)).expect("open");
                        let mut got = 0usize;
                        for block in ds.data.chunks(CHUNK * ds.d) {
                            let t = Instant::now();
                            let scores = c.push(block).expect("push");
                            let dt = t.elapsed().as_secs_f64();
                            pushed.fetch_add(1, Ordering::SeqCst);
                            let rerouted = c
                                .take_notices()
                                .iter()
                                .any(|n| n.code == STATUS_REROUTED);
                            if rerouted {
                                // The stall a client actually feels when its
                                // session re-shards mid-push.
                                pauses.push(dt);
                            } else if block.len() == CHUNK * ds.d {
                                latencies.push(dt);
                            }
                            got += scores.len();
                        }
                        let closed = c.close().expect("close");
                        c.take_notices();
                        got += closed.scores.len();
                        assert_eq!(got, ds.n(), "every sample must score");
                        done += 1;
                        scored += got as u64;
                    }
                    (done, scored, latencies, pauses)
                }));
            }
            // Killer: wait for a third of the total pushes, sever the
            // proxy, then time the router's first successful re-admission.
            let router = &router;
            let proxy = &proxy;
            let pushed = &pushed;
            let killer = scope.spawn(move || -> Option<f64> {
                while pushed.load(Ordering::SeqCst) < total_pushes / 3 {
                    std::thread::sleep(std::time::Duration::from_millis(2));
                }
                let t_kill = Instant::now();
                proxy.kill();
                let deadline = t_kill + std::time::Duration::from_secs(30);
                while Instant::now() < deadline {
                    if router.stats().rerouted >= 1 {
                        return Some(t_kill.elapsed().as_secs_f64());
                    }
                    std::thread::sleep(std::time::Duration::from_millis(2));
                }
                None
            });
            for h in handles {
                let (done, scored, lat, pauses) = h.join().expect("client thread");
                sessions += done;
                total_samples += scored;
                all_latencies.extend(lat);
                all_pauses.extend(pauses);
            }
            recovery_secs = killer.join().expect("killer thread");
        });
        let wall = t0.elapsed().as_secs_f64().max(1e-9);
        all_latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
        all_pauses.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let stats = router.stats();
        assert_eq!(stats.lost, 0, "the kill must re-shard sessions, not lose them");
        router.stop();
        drop(proxy);
        for (server, net) in workers {
            net.stop();
            let mut server = server;
            loop {
                match Arc::try_unwrap(server) {
                    Ok(s) => {
                        s.shutdown().expect("shutdown");
                        break;
                    }
                    Err(s) => {
                        server = s;
                        std::thread::sleep(std::time::Duration::from_millis(5));
                    }
                }
            }
        }
        println!(
            "router_sessions/{}  {} sessions from {} clients over {} workers in {:.3} s — \
             {:.2} sessions/s, push p50 {:.3} ms / p99 {:.3} ms, reshard pause p99 {:.3} ms \
             ({} reshards), recovery {} ms, {} rerouted / {} lost",
            mode.as_str(),
            sessions,
            CLIENTS,
            WORKERS,
            wall,
            sessions as f64 / wall,
            percentile_ms(&all_latencies, 0.50),
            percentile_ms(&all_latencies, 0.99),
            percentile_ms(&all_pauses, 0.99),
            all_pauses.len(),
            recovery_secs.map_or("n/a".into(), |s| format!("{:.1}", s * 1e3)),
            stats.rerouted,
            stats.lost
        );
        rows.push(Row {
            mode: mode.as_str(),
            sessions,
            samples: total_samples,
            wall_secs: wall,
            latencies: all_latencies,
            reshard_pauses: all_pauses,
            recovery_secs,
            rerouted: stats.rerouted,
            lost: stats.lost,
        });
    }

    let mut json = String::from("{\n  \"bench\": \"router_sessions\",\n");
    json.push_str(&format!(
        "  \"workers\": {WORKERS},\n  \"clients\": {CLIENTS},\n  \"chunk\": {CHUNK},\n  \
         \"checkpoint_pushes\": {CHECKPOINT_PUSHES},\n  \"rounds\": {rounds},\n  \
         \"samples_per_session\": {samples},\n  \"rows\": [\n"
    ));
    for (i, r) in rows.iter().enumerate() {
        // null when nothing was measured — never a fabricated 0.0.
        let (p50, p99) = if r.latencies.is_empty() {
            ("null".into(), "null".into())
        } else {
            (
                format!("{:.4}", percentile_ms(&r.latencies, 0.50)),
                format!("{:.4}", percentile_ms(&r.latencies, 0.99)),
            )
        };
        let pause_p99 = if r.reshard_pauses.is_empty() {
            "null".into()
        } else {
            format!("{:.4}", percentile_ms(&r.reshard_pauses, 0.99))
        };
        let recovery = r.recovery_secs.map_or("null".into(), |s| format!("{:.4}", s * 1e3));
        json.push_str(&format!(
            "    {{\"mode\": \"{}\", \"sessions\": {}, \"wall_secs\": {:.6}, \
             \"sessions_per_sec\": {:.3}, \"samples_per_sec\": {:.1}, \
             \"push_latency_p50_ms\": {p50}, \"push_latency_p99_ms\": {p99}, \
             \"reshard_pause_p99_ms\": {pause_p99}, \"recovery_ms\": {recovery}, \
             \"rerouted\": {}, \"lost\": {}, \"latency_samples\": {}, \
             \"reshard_samples\": {}}}{}\n",
            r.mode,
            r.sessions,
            r.wall_secs,
            r.sessions as f64 / r.wall_secs,
            r.samples as f64 / r.wall_secs,
            r.rerouted,
            r.lost,
            r.latencies.len(),
            r.reshard_pauses.len(),
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    json.push_str("  ]\n}\n");
    match std::fs::write("BENCH_router.json", &json) {
        Ok(()) => println!("wrote BENCH_router.json"),
        Err(e) => eprintln!("could not write BENCH_router.json: {e}"),
    }
}
