//! Bench: Figure 20 — bypass channel latency through the fabric
//! (DMA → pblock → switches → DMA), native and PJRT paths.

mod bench_util;
use bench_util::Bench;

use fsead::exp::{fig20, ExpCtx};

fn main() {
    let b = Bench::new("fig20");
    let ctx = ExpCtx::default();
    b.run("short/native", || {
        fig20::measure_short_path(&ctx, false).unwrap();
    });
    b.run("full/native", || {
        fig20::measure_full_path(&ctx, false).unwrap();
    });
    if ctx.artifacts_available() {
        b.run("short/pjrt", || {
            fig20::measure_short_path(&ctx, true).unwrap();
        });
    }
    println!("  -> paper: 0.77 ms short path, 0.80 ms full path (PYNQ-driver bound)");
}
