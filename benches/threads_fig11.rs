//! Bench: Figure 11 — multi-threaded CPU baseline vs thread count
//! (xStream, HTTP-3 prefix), per-sample mutex+barrier synchronisation.

mod bench_util;
use bench_util::{cap, Bench};

use fsead::detectors::{DetectorKind, DetectorSpec};
use fsead::ensemble::run_threaded;

fn main() {
    let b = Bench::new("fig11");
    let ds = fsead::data::Dataset::load("http3", 42, None).unwrap().prefix(cap());
    let kind = DetectorKind::XStream;
    let spec = DetectorSpec::new(kind, ds.d, 7 * kind.pblock_r(), 42);
    let mut base = None;
    for threads in [1usize, 2, 4, 8, 16] {
        let t = b.run(&format!("xstream/http3/threads={threads}"), || {
            run_threaded(&spec, &ds, threads);
        });
        let b0 = *base.get_or_insert(t);
        println!("  -> speedup vs 1 thread: {:.2}x (paper peaks at 4 threads)", b0 / t);
    }
}
