//! Bench: Table 13 — RM swap cost in this system (CPU RM rebuild and PJRT
//! artifact compile) next to the calibrated DFX download model.

mod bench_util;
use bench_util::Bench;

use fsead::config::{DetectorHyper, RmKind};
use fsead::detectors::DetectorKind;
use fsead::fabric::pblock::Pblock;
use fsead::fabric::reconfig::{DfxManager, ReconfigModel};

fn main() {
    let b = Bench::new("table13");
    let hyper = DetectorHyper::default();
    let mgr = DfxManager::default();
    let warmup: Vec<f32> = (0..hyper.window * 3).map(|i| (i as f32 * 0.31).sin()).collect();
    for kind in DetectorKind::ALL {
        let mut pb = Pblock::new(1);
        b.run(&format!("swap-cpu/{}", kind.as_str()), || {
            mgr.reconfigure(
                &mut pb,
                RmKind::Detector(kind),
                8,
                3,
                1,
                &hyper,
                &warmup,
                None,
                false,
                1,
            )
            .unwrap();
        });
    }
    if std::path::Path::new("artifacts/manifest.txt").exists() {
        let rt = fsead::runtime::Runtime::start("artifacts").unwrap();
        for name in ["loda_d3_r4", "rshash_d3_r4", "xstream_d3_r4"] {
            // First compile is the "bitstream download"; cache hits after.
            let cold = rt.handle().precompile(name).unwrap();
            println!("table13/compile-cold/{name}  time: [{:.1} ms]", cold * 1e3);
            b.run(&format!("compile-cached/{name}"), || {
                rt.handle().precompile(name).unwrap();
            });
        }
    }
    let model = ReconfigModel::default();
    println!(
        "  -> DFX download model: RP-1 {:.1} ms … RP-6 {:.1} ms, COMBO3 {:.1} ms (paper: 604–610 / 580)",
        model.time_ms("RP-1", true).unwrap(),
        model.time_ms("RP-6", true).unwrap(),
        model.time_ms("COMBO3", true).unwrap()
    );
}
