//! Bench: live DFX — swap latency and dark-window flit loss vs the Table-13
//! model, measured on a streaming fabric (2 Loda pblocks on one stream,
//! chunk 16). Each timed pass hot-swaps pblock 1 mid-stream while pblock 2
//! keeps scoring; a no-swap pass of the same workload gives the overhead
//! baseline.
//!
//! Emits `BENCH_dfx.json`: per-mode wall times with and without a swap, the
//! modelled download latency, the measured in-system swap cost (RM replace +
//! reset inside the service thread), the dark-window length and the flits
//! actually lost/bypassed — plus the model's residual against the paper's
//! Table 13 measurement for RP-1 (gate: residual ≤ 6 ms, the bound the
//! `table13` unit tests hold every block to).

mod bench_util;
use bench_util::{cap, Bench};

use fsead::config::{DarkPolicy, FseadConfig, PblockCfg, RmKind};
use fsead::data::synth::{generate_profile, DatasetProfile};
use fsead::detectors::DetectorKind;
use fsead::ensemble::ExecMode;
use fsead::fabric::hotswap::model_dark_flits;
use fsead::fabric::reconfig::ReconfigModel;
use fsead::fabric::Fabric;

const CHUNK: usize = 16;
/// Modelled stream rate for ms → flit conversion: slow enough that the
/// ~606 ms download maps to a dark window well inside the bench stream.
const RATE: f64 = 2_000.0;
/// Paper Table 13, RP-1 Identity → Function (ms).
const PAPER_RP1_MS: f64 = 606.3;

fn topology(exec: ExecMode) -> FseadConfig {
    let mut cfg = FseadConfig::default();
    cfg.use_fpga = false;
    cfg.exec = exec;
    cfg.chunk = CHUNK;
    cfg.dfx.samples_per_sec = RATE;
    cfg.dfx.policy = DarkPolicy::Bypass;
    for id in 1..=2usize {
        cfg.pblocks.push(PblockCfg {
            id,
            rm: RmKind::Detector(DetectorKind::Loda),
            r: 2,
            stream: 0,
            lanes: 0,
        });
    }
    cfg
}

struct Row {
    mode: &'static str,
    secs_noswap: f64,
    secs_swap: f64,
    model_ms: f64,
    actual_ms: f64,
    dark_flits: u64,
    flits_lost: u64,
}

fn main() {
    let bench = Bench::new("dfx_swap");
    let n = cap();
    let p = DatasetProfile { name: "dfx", n, d: 4, outliers: n / 100, clusters: 3 };
    let ds = generate_profile(&p, 42);
    let n = ds.n();
    let total_flits = n.div_ceil(CHUNK) as u64;
    // Table-13-modelled dark window, clamped so it always completes inside
    // the bench stream (tiny FSEAD_BENCH_SAMPLES runs stay green).
    let model_only_ms = ReconfigModel::default().time_ms_pblock(1, true).unwrap();
    let dark = model_dark_flits(model_only_ms, RATE, CHUNK).min(total_flits / 2).max(1);

    let mut rows: Vec<Row> = Vec::new();
    for mode in ExecMode::ALL {
        // Baseline: the same workload with no swap scheduled.
        let mut plain = Fabric::new(topology(mode), vec![ds.clone()]).unwrap();
        let secs_noswap = bench.run(&format!("noswap/{}", mode.as_str()), || {
            plain.reset_all().unwrap();
            let out = plain.run().unwrap();
            assert!(out.swap_events.is_empty());
        });

        // Live: hot-swap pblock 1 (Loda → Loda keeps the workload constant)
        // mid-stream on every pass; the dark window comes from the Table-13
        // model at RATE.
        let mut live = Fabric::new(topology(mode), vec![ds.clone()]).unwrap();
        let mut last = None;
        let secs_swap = bench.run(&format!("swap/{}", mode.as_str()), || {
            live.reset_all().unwrap();
            live.schedule_swap(1, 10, RmKind::Detector(DetectorKind::Loda), 2, Some(dark))
                .unwrap();
            let out = live.run().unwrap();
            assert_eq!(out.swap_events.len(), 1, "swap must execute mid-stream");
            // Pblock 2 streams through a full pass regardless of the swap.
            assert_eq!(out.pblock_scores[&2].len(), n);
            last = Some(out.swap_events[0].clone());
        });
        let ev = last.expect("at least one timed pass");
        assert_eq!(ev.dark_flits, dark, "dark window must follow the schedule");
        assert!(ev.dark_complete, "bench stream must cover the dark window");
        assert_eq!(ev.bypassed + ev.dropped, ev.dark_flits, "every dark flit is accounted");
        println!(
            "  -> {}: swap pass {:.1} ms vs {:.1} ms plain; model {:.1} ms, in-system swap \
             {:.3} ms, dark {} flits ({} bypassed)",
            mode.as_str(),
            secs_swap * 1e3,
            secs_noswap * 1e3,
            ev.model_ms,
            ev.actual_ms,
            ev.dark_flits,
            ev.bypassed
        );
        rows.push(Row {
            mode: mode.as_str(),
            secs_noswap,
            secs_swap,
            model_ms: ev.model_ms,
            actual_ms: ev.actual_ms,
            dark_flits: ev.dark_flits,
            flits_lost: ev.bypassed + ev.dropped,
        });
    }

    // Gate: the calibrated model must sit within the Table-13 residual the
    // unit tests enforce (±6 ms of every paper cell).
    let model_ms = rows[0].model_ms;
    let residual_ms = (model_ms - PAPER_RP1_MS).abs();
    assert!(residual_ms <= 6.0, "model {model_ms:.1} ms vs paper {PAPER_RP1_MS:.1} ms");
    println!("  -> RP-1 model residual vs paper Table 13: {residual_ms:.2} ms");

    let mut json = String::from("{\n  \"bench\": \"dfx_swap\",\n");
    json.push_str(&format!(
        "  \"n\": {n},\n  \"chunk\": {CHUNK},\n  \"samples_per_sec\": {RATE},\n  \
         \"paper_rp1_ms\": {PAPER_RP1_MS},\n  \"model_residual_ms\": {residual_ms:.3},\n  \
         \"rows\": [\n"
    ));
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"mode\": \"{}\", \"seconds_noswap\": {:.6}, \"seconds_swap\": {:.6}, \
             \"model_ms\": {:.3}, \"actual_ms\": {:.4}, \"dark_flits\": {}, \
             \"flits_lost\": {}}}{}\n",
            r.mode,
            r.secs_noswap,
            r.secs_swap,
            r.model_ms,
            r.actual_ms,
            r.dark_flits,
            r.flits_lost,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    json.push_str("  ]\n}\n");
    match std::fs::write("BENCH_dfx.json", &json) {
        Ok(()) => println!("wrote BENCH_dfx.json"),
        Err(e) => eprintln!("could not write BENCH_dfx.json: {e}"),
    }
}
