//! Bench: persistent session server — sessions/sec and per-chunk push→score
//! round-trip latency under concurrent client load, in both execution
//! modes, on a 4-partition Loda topology (≥ 4 concurrent sessions).
//!
//! Emits `BENCH_serve.json` with sessions/sec, samples/sec and the p50/p99
//! per-chunk latency for the perf trajectory; CI runs a smoke pass on every
//! PR and uploads it with the other BENCH artifacts.

#[allow(dead_code)] // only `cap` is used from the shared harness here
mod bench_util;
use bench_util::cap;

use fsead::config::{FseadConfig, PblockCfg, RmKind};
use fsead::detectors::DetectorKind;
use fsead::ensemble::ExecMode;
use fsead::exp::serve::{synthetic_load, LoadReport};
use fsead::fabric::server::FabricServer;

const PARTITIONS: usize = 4;
const CLIENTS: usize = 4;
const CHUNK: usize = 64;

fn topology(exec: ExecMode) -> FseadConfig {
    let mut cfg =
        FseadConfig { use_fpga: false, exec, chunk: CHUNK, ..FseadConfig::default() };
    for id in 1..=PARTITIONS {
        cfg.pblocks.push(PblockCfg {
            id,
            rm: RmKind::Detector(DetectorKind::Loda),
            r: 2,
            stream: 0,
            lanes: 0,
        });
    }
    cfg
}

fn main() {
    let rounds: usize =
        std::env::var("FSEAD_BENCH_ITERS").ok().and_then(|v| v.parse().ok()).unwrap_or(3);
    let samples = (cap() / CLIENTS).max(CHUNK * 4);
    let mut rows: Vec<(&str, LoadReport)> = Vec::new();
    for mode in ExecMode::ALL {
        let server = FabricServer::start(topology(mode)).expect("server start");
        let report =
            synthetic_load(&server, CLIENTS, rounds, samples).expect("synthetic load");
        server.shutdown().expect("shutdown");
        println!(
            "serve_sessions/{}  {} sessions in {:.3} s — {:.2} sessions/s, {:.0} samples/s, \
             chunk p50 {:.3} ms / p99 {:.3} ms",
            mode.as_str(),
            report.sessions,
            report.wall_secs,
            report.sessions_per_sec,
            report.samples_per_sec,
            report.chunk_latency_p50_ms,
            report.chunk_latency_p99_ms
        );
        rows.push((mode.as_str(), report));
    }

    let mut json = String::from("{\n  \"bench\": \"serve_sessions\",\n");
    json.push_str(&format!(
        "  \"partitions\": {PARTITIONS},\n  \"clients\": {CLIENTS},\n  \"rounds\": {rounds},\n  \
         \"samples_per_session\": {samples},\n  \"chunk\": {CHUNK},\n  \"rows\": [\n"
    ));
    for (i, (mode, r)) in rows.iter().enumerate() {
        // null percentiles when nothing was measured (async drain mode) —
        // never a fabricated 0.0.
        let (p50, p99) = if r.latency_samples > 0 {
            (format!("{:.4}", r.chunk_latency_p50_ms), format!("{:.4}", r.chunk_latency_p99_ms))
        } else {
            ("null".into(), "null".into())
        };
        json.push_str(&format!(
            "    {{\"mode\": \"{mode}\", \"sessions\": {}, \"wall_secs\": {:.6}, \
             \"sessions_per_sec\": {:.3}, \"samples_per_sec\": {:.1}, \
             \"chunk_latency_p50_ms\": {p50}, \"chunk_latency_p99_ms\": {p99}, \
             \"latency_samples\": {}}}{}\n",
            r.sessions,
            r.wall_secs,
            r.sessions_per_sec,
            r.samples_per_sec,
            r.latency_samples,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    json.push_str("  ]\n}\n");
    match std::fs::write("BENCH_serve.json", &json) {
        Ok(()) => println!("wrote BENCH_serve.json"),
        Err(e) => eprintln!("could not write BENCH_serve.json: {e}"),
    }
}
