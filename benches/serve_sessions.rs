//! Bench: persistent session server — sessions/sec and per-chunk push→score
//! round-trip latency under concurrent client load, in both execution
//! modes, on a 4-partition Loda topology (≥ 4 concurrent sessions).
//!
//! The operator plane runs alongside each pass with a 10 Hz `/metrics`
//! scraper, so the bench also measures scrape latency (and exercises the
//! "a live scrape never perturbs the data plane" claim under load).
//!
//! Emits `BENCH_serve.json` with sessions/sec, samples/sec, the p50/p99
//! per-chunk latency and the p50/p99 scrape latency for the perf
//! trajectory; CI runs a smoke pass on every PR and uploads it with the
//! other BENCH artifacts.

#[allow(dead_code)] // only `cap` is used from the shared harness here
mod bench_util;
use bench_util::cap;

use std::io::{Read, Write};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use fsead::config::{FseadConfig, PblockCfg, RmKind};
use fsead::detectors::DetectorKind;
use fsead::ensemble::ExecMode;
use fsead::exp::serve::{synthetic_load, LoadReport};
use fsead::fabric::operator::OperatorServer;
use fsead::fabric::server::FabricServer;

const PARTITIONS: usize = 4;
const CLIENTS: usize = 4;
const CHUNK: usize = 64;
const SCRAPE_PERIOD: Duration = Duration::from_millis(100);

fn topology(exec: ExecMode) -> FseadConfig {
    let mut cfg =
        FseadConfig { use_fpga: false, exec, chunk: CHUNK, ..FseadConfig::default() };
    for id in 1..=PARTITIONS {
        cfg.pblocks.push(PblockCfg {
            id,
            rm: RmKind::Detector(DetectorKind::Loda),
            r: 2,
            stream: 0,
            lanes: 0,
        });
    }
    cfg
}

/// One GET /metrics round-trip; returns its wall-clock latency.
fn scrape(addr: std::net::SocketAddr) -> Duration {
    let t = Instant::now();
    let mut stream = std::net::TcpStream::connect(addr).expect("connect operator");
    stream
        .write_all(b"GET /metrics HTTP/1.1\r\nHost: bench\r\nContent-Length: 0\r\n\r\n")
        .expect("write scrape");
    let mut body = String::new();
    stream.read_to_string(&mut body).expect("read scrape");
    assert!(body.contains("fsead_server_sessions_served_total"), "malformed scrape");
    t.elapsed()
}

fn percentile_ms(sorted_secs: &[f64], p: f64) -> f64 {
    if sorted_secs.is_empty() {
        return 0.0;
    }
    let idx = ((sorted_secs.len() - 1) as f64 * p).round() as usize;
    sorted_secs[idx] * 1e3
}

fn main() {
    let rounds: usize =
        std::env::var("FSEAD_BENCH_ITERS").ok().and_then(|v| v.parse().ok()).unwrap_or(3);
    let samples = (cap() / CLIENTS).max(CHUNK * 4);
    let mut rows: Vec<(&str, LoadReport, Vec<f64>)> = Vec::new();
    for mode in ExecMode::ALL {
        let server = Arc::new(FabricServer::start(topology(mode)).expect("server start"));
        let operator = OperatorServer::start("127.0.0.1:0", None, Arc::clone(&server))
            .expect("operator start");
        let addr = operator.addr();
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let scraper = std::thread::spawn(move || {
            let mut latencies: Vec<f64> = Vec::new();
            while !stop2.load(Ordering::Relaxed) {
                latencies.push(scrape(addr).as_secs_f64());
                std::thread::sleep(SCRAPE_PERIOD);
            }
            latencies
        });
        let report =
            synthetic_load(&server, CLIENTS, rounds, samples).expect("synthetic load");
        stop.store(true, Ordering::Relaxed);
        let mut scrape_secs = scraper.join().expect("scraper thread");
        scrape_secs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        operator.stop();
        Arc::try_unwrap(server)
            .ok()
            .expect("operator stopped, server sole-owned")
            .shutdown()
            .expect("shutdown");
        println!(
            "serve_sessions/{}  {} sessions in {:.3} s — {:.2} sessions/s, {:.0} samples/s, \
             chunk p50 {:.3} ms / p99 {:.3} ms, scrape p50 {:.3} ms / p99 {:.3} ms ({} scrapes)",
            mode.as_str(),
            report.sessions,
            report.wall_secs,
            report.sessions_per_sec,
            report.samples_per_sec,
            report.chunk_latency_p50_ms,
            report.chunk_latency_p99_ms,
            percentile_ms(&scrape_secs, 0.50),
            percentile_ms(&scrape_secs, 0.99),
            scrape_secs.len()
        );
        rows.push((mode.as_str(), report, scrape_secs));
    }

    let mut json = String::from("{\n  \"bench\": \"serve_sessions\",\n");
    json.push_str(&format!(
        "  \"partitions\": {PARTITIONS},\n  \"clients\": {CLIENTS},\n  \"rounds\": {rounds},\n  \
         \"samples_per_session\": {samples},\n  \"chunk\": {CHUNK},\n  \
         \"scrape_hz\": {:.0},\n  \"rows\": [\n",
        1.0 / SCRAPE_PERIOD.as_secs_f64()
    ));
    for (i, (mode, r, scrape_secs)) in rows.iter().enumerate() {
        // null percentiles when nothing was measured (async drain mode, or
        // a pass too short for a single scrape) — never a fabricated 0.0.
        let (p50, p99) = if r.latency_samples > 0 {
            (format!("{:.4}", r.chunk_latency_p50_ms), format!("{:.4}", r.chunk_latency_p99_ms))
        } else {
            ("null".into(), "null".into())
        };
        let (s50, s99) = if scrape_secs.is_empty() {
            ("null".into(), "null".into())
        } else {
            (
                format!("{:.4}", percentile_ms(scrape_secs, 0.50)),
                format!("{:.4}", percentile_ms(scrape_secs, 0.99)),
            )
        };
        json.push_str(&format!(
            "    {{\"mode\": \"{mode}\", \"sessions\": {}, \"wall_secs\": {:.6}, \
             \"sessions_per_sec\": {:.3}, \"samples_per_sec\": {:.1}, \
             \"chunk_latency_p50_ms\": {p50}, \"chunk_latency_p99_ms\": {p99}, \
             \"latency_samples\": {}, \"scrape_latency_p50_ms\": {s50}, \
             \"scrape_latency_p99_ms\": {s99}, \"scrape_samples\": {}}}{}\n",
            r.sessions,
            r.wall_secs,
            r.sessions_per_sec,
            r.samples_per_sec,
            r.latency_samples,
            scrape_secs.len(),
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    json.push_str("  ]\n}\n");
    match std::fs::write("BENCH_serve.json", &json) {
        Ok(()) => println!("wrote BENCH_serve.json"),
        Err(e) => eprintln!("could not write BENCH_serve.json: {e}"),
    }
}
