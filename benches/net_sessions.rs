//! Bench: the network serving plane — sessions/sec and per-push
//! push→score round-trip latency with 64 concurrent loopback clients
//! speaking the `fsead net` frame protocol, in both execution modes, on a
//! 4-partition Loda topology multiplexed 16 sessions deep.
//!
//! Every client is a real [`NetClient`] over TCP: each push pays the frame
//! codec, the socket hop and the lock-step score wait, so the numbers are
//! the wire protocol's overhead on top of the in-process figures from
//! `benches/serve_sessions.rs`.
//!
//! Emits `BENCH_net.json`; CI runs a smoke pass on every PR, validates the
//! JSON and uploads it with the other BENCH artifacts.

#[allow(dead_code)] // only `cap` is used from the shared harness here
mod bench_util;
use bench_util::cap;

use std::sync::Arc;
use std::time::Instant;

use fsead::config::{FseadConfig, PblockCfg, RmKind};
use fsead::data::synth::{generate_profile, DatasetProfile};
use fsead::detectors::DetectorKind;
use fsead::ensemble::ExecMode;
use fsead::fabric::net::NetServer;
use fsead::fabric::net_client::NetClient;
use fsead::fabric::server::FabricServer;

const PARTITIONS: usize = 4;
const CLIENTS: usize = 64;
const CHUNK: usize = 64;
/// Sessions multiplexed per partition — 4 × 16 slots admit all 64 clients.
const MUX: usize = 16;

fn topology(exec: ExecMode) -> FseadConfig {
    let mut cfg = FseadConfig { use_fpga: false, exec, chunk: CHUNK, ..FseadConfig::default() };
    cfg.server.sessions_per_partition = MUX;
    for id in 1..=PARTITIONS {
        cfg.pblocks.push(PblockCfg {
            id,
            rm: RmKind::Detector(DetectorKind::Loda),
            r: 2,
            stream: 0,
            lanes: 0,
        });
    }
    cfg
}

fn percentile_ms(sorted_secs: &[f64], p: f64) -> f64 {
    if sorted_secs.is_empty() {
        return 0.0;
    }
    let idx = ((sorted_secs.len() - 1) as f64 * p).round() as usize;
    sorted_secs[idx] * 1e3
}

struct Row {
    mode: &'static str,
    sessions: u64,
    samples: u64,
    wall_secs: f64,
    latencies: Vec<f64>,
}

fn main() {
    let rounds: usize =
        std::env::var("FSEAD_BENCH_ITERS").ok().and_then(|v| v.parse().ok()).unwrap_or(2);
    let samples = (cap() / CLIENTS).max(CHUNK * 2);
    let mut rows: Vec<Row> = Vec::new();
    for mode in ExecMode::ALL {
        let cfg = topology(mode);
        let window = cfg.hyper.window;
        let server = Arc::new(FabricServer::start(cfg).expect("server start"));
        // Head-room over the client count: the cap is a flood valve here,
        // not the thing under test.
        let net = NetServer::start_with_limit("127.0.0.1:0", Arc::clone(&server), CLIENTS + 8)
            .expect("net start");
        let addr = net.addr().to_string();
        let t0 = Instant::now();
        let mut all_latencies: Vec<f64> = Vec::new();
        let mut sessions = 0u64;
        let mut total_samples = 0u64;
        std::thread::scope(|scope| {
            let mut handles = Vec::new();
            for client in 0..CLIENTS {
                let addr = &addr;
                handles.push(scope.spawn(move || -> (u64, u64, Vec<f64>) {
                    let mut latencies = Vec::new();
                    let mut done = 0u64;
                    let mut scored = 0u64;
                    for round in 0..rounds {
                        let profile = DatasetProfile {
                            name: "net",
                            n: samples,
                            d: 3,
                            outliers: samples / 50,
                            clusters: 2,
                        };
                        let ds = generate_profile(&profile, (client * 131 + round) as u64 + 1);
                        let mut c = NetClient::connect(addr).expect("connect");
                        c.open(ds.d, None, ds.warmup(window)).expect("open");
                        let mut got = 0usize;
                        for block in ds.data.chunks(CHUNK * ds.d) {
                            let t = Instant::now();
                            let scores = c.push(block).expect("push");
                            if block.len() == CHUNK * ds.d {
                                // Full flit ⇒ the reply carried its score
                                // flit — a complete wire round-trip.
                                latencies.push(t.elapsed().as_secs_f64());
                            }
                            got += scores.len();
                        }
                        let closed = c.close().expect("close");
                        got += closed.scores.len();
                        assert_eq!(got, ds.n(), "every sample must score");
                        done += 1;
                        scored += got as u64;
                    }
                    (done, scored, latencies)
                }));
            }
            for h in handles {
                let (done, scored, lat) = h.join().expect("client thread");
                sessions += done;
                total_samples += scored;
                all_latencies.extend(lat);
            }
        });
        let wall = t0.elapsed().as_secs_f64().max(1e-9);
        all_latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
        net.stop();
        let mut server = server;
        loop {
            match Arc::try_unwrap(server) {
                Ok(s) => {
                    s.shutdown().expect("shutdown");
                    break;
                }
                Err(s) => {
                    server = s;
                    std::thread::sleep(std::time::Duration::from_millis(5));
                }
            }
        }
        println!(
            "net_sessions/{}  {} sessions from {} clients in {:.3} s — {:.2} sessions/s, \
             {:.0} samples/s, push p50 {:.3} ms / p99 {:.3} ms ({} round-trips)",
            mode.as_str(),
            sessions,
            CLIENTS,
            wall,
            sessions as f64 / wall,
            total_samples as f64 / wall,
            percentile_ms(&all_latencies, 0.50),
            percentile_ms(&all_latencies, 0.99),
            all_latencies.len()
        );
        rows.push(Row {
            mode: mode.as_str(),
            sessions,
            samples: total_samples,
            wall_secs: wall,
            latencies: all_latencies,
        });
    }

    let mut json = String::from("{\n  \"bench\": \"net_sessions\",\n");
    json.push_str(&format!(
        "  \"partitions\": {PARTITIONS},\n  \"clients\": {CLIENTS},\n  \"mux\": {MUX},\n  \
         \"rounds\": {rounds},\n  \"samples_per_session\": {samples},\n  \"chunk\": {CHUNK},\n  \
         \"rows\": [\n"
    ));
    for (i, r) in rows.iter().enumerate() {
        // null percentiles when nothing was measured — never a fabricated 0.0.
        let (p50, p99) = if r.latencies.is_empty() {
            ("null".into(), "null".into())
        } else {
            (
                format!("{:.4}", percentile_ms(&r.latencies, 0.50)),
                format!("{:.4}", percentile_ms(&r.latencies, 0.99)),
            )
        };
        json.push_str(&format!(
            "    {{\"mode\": \"{}\", \"sessions\": {}, \"wall_secs\": {:.6}, \
             \"sessions_per_sec\": {:.3}, \"samples_per_sec\": {:.1}, \
             \"push_latency_p50_ms\": {p50}, \"push_latency_p99_ms\": {p99}, \
             \"latency_samples\": {}}}{}\n",
            r.mode,
            r.sessions,
            r.wall_secs,
            r.sessions as f64 / r.wall_secs,
            r.samples as f64 / r.wall_secs,
            r.latencies.len(),
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    json.push_str("  ]\n}\n");
    match std::fs::write("BENCH_net.json", &json) {
        Ok(()) => println!("wrote BENCH_net.json"),
        Err(e) => eprintln!("could not write BENCH_net.json: {e}"),
    }
}
